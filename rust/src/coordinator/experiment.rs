//! Experiment presets and reports: one [`Scenario`] per paper experiment,
//! and the [`Report`] type whose fields are exactly the numbers the paper
//! quotes (sustained Gbps, makespan, median runtime, median input transfer
//! time, error count).
//!
//! ## Per-source NIC aggregation format
//!
//! Multi-source runs monitor every serving NIC separately:
//! [`Report::per_node_series`] holds one [`BinSeries`] per submit node
//! and [`Report::per_dtn_series`] one per dedicated data node (all with
//! the same bin width), and the aggregate [`Report::series`] is their
//! element-wise sum — bin `b` of the aggregate equals
//! `Σ_source per_source_series[source][b]` ([`BinSeries::sum`]). The
//! 5-minute [`Report::series_5min`] figure is rebinned from the
//! aggregate, exactly like the paper's monitoring plots; per-source
//! figures can be rebinned the same way.

use super::engine::{Engine, EngineResult, EngineSpec};
use crate::metrics::BinSeries;
use crate::mover::{
    AdmissionConfig, ChaosTimeline, FaultPlan, MoverStats, RouterPolicy, RouterStats, SiteSelector,
    SourcePlan, SourceSelector,
};
use crate::netsim::solver::SolverKind;
use crate::netsim::topology::TestbedSpec;
use crate::transfer::ThrottlePolicy;
use crate::util::units::{Gbps, SimTime};
use crate::util::OnlineStats;
use anyhow::Result;

/// The experiments of the paper (see DESIGN.md's experiment index), plus
/// the data-mover variants the paper could only speculate about.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// §III / Fig. 1: LAN, 10k × 2 GB, queue throttle disabled.
    LanPaper,
    /// §IV / Fig. 2: WAN (NY workers), same workload.
    WanPaper,
    /// WanPaper re-run under the dynamic per-flow TCP solver
    /// ([`SolverKind::TcpDynamic`]): same topology and workload, but
    /// every flow replays slow start, AIMD and Bernoulli loss against
    /// the 58 ms RTT instead of jumping to its Mathis steady state.
    WanTcpDynamic,
    /// §III narrative: same as LanPaper but with the default disk-load
    /// transfer-queue throttle — paper observed ~2× the makespan.
    LanDefaultQueue,
    /// §II narrative: submit pod behind the Calico VPN overlay — paper
    /// observed a ~25 Gbps ceiling.
    LanVpn,
    /// LanPaper with per-owner fair-share admission at the paper's ~200
    /// concurrent-transfer operating point.
    LanFairShare,
    /// LanPaper with a 4-shard shadow pool (multi-shard data mover).
    LanSharded4,
    /// The scale-out scenario the paper motivates: the same burst split
    /// across 4 submit nodes (4 × 100 Gbps NICs) by a pool router.
    LanMultiSubmit4,
    /// Heterogeneous submit fleet: 2 × 100 Gbps + 2 × 25 Gbps NICs,
    /// routed weighted-by-capacity (the ROADMAP's mixed-fleet preset).
    Hetero25100,
    /// Chaos scenario: the 4-node scale-out pool with submit node 1
    /// killed mid-burst and recovered later; the router drains, retries
    /// and work-steals so the burst finishes at line rate.
    KillRecover4,
    /// The DTN offload the paper's caveat motivates: one submit node
    /// handles scheduling only, while a fleet of 4 dedicated data nodes
    /// (4 × 100 Gbps NICs) serves every sandbox byte — the Petascale
    /// DTN deployment shape.
    DtnOffload4,
    /// Cache-aware source selection over a 4-DTN fleet: 8 extents
    /// staged block-wise across the nodes (each node's page cache holds
    /// exactly its share) over spinning bulk stores, with transfers
    /// steered to the node already holding their extent hot — the
    /// Petascale DTN lesson that fleets only reach rated throughput
    /// when endpoint state drives placement.
    CacheAffine4,
    /// The Petascale DTN transfer-matrix shape the paper's DTN work
    /// benchmarked for a week: 3 federated sites joined by WAN pair
    /// links, each hosting one submit node, 2 dedicated data nodes and
    /// 2 worker hosts, with round-robin site selection deliberately
    /// forcing cross-site traffic so every site×site cell of the
    /// goodput matrix carries bytes (fair-share admission across 3
    /// owners, like the shared testbed).
    PetascaleWeek3x2,
}

impl Scenario {
    pub fn name(&self) -> &'static str {
        match self {
            Scenario::LanPaper => "fig1-lan",
            Scenario::WanPaper => "fig2-wan",
            Scenario::WanTcpDynamic => "wan-tcp",
            Scenario::LanDefaultQueue => "queue-default",
            Scenario::LanVpn => "vpn-overlay",
            Scenario::LanFairShare => "fair-share",
            Scenario::LanSharded4 => "sharded-4",
            Scenario::LanMultiSubmit4 => "multi-submit-4",
            Scenario::Hetero25100 => "hetero-25-100",
            Scenario::KillRecover4 => "kill-recover-4",
            Scenario::DtnOffload4 => "dtn-offload-4",
            Scenario::CacheAffine4 => "cache-affine-4",
            Scenario::PetascaleWeek3x2 => "petascale-week-3x2",
        }
    }

    pub fn spec(&self) -> EngineSpec {
        match self {
            Scenario::LanPaper => {
                EngineSpec::paper(TestbedSpec::lan_paper(), ThrottlePolicy::Disabled)
            }
            Scenario::WanPaper => {
                EngineSpec::paper(TestbedSpec::wan_paper(), ThrottlePolicy::Disabled)
            }
            Scenario::WanTcpDynamic => {
                let mut spec =
                    EngineSpec::paper(TestbedSpec::wan_paper(), ThrottlePolicy::Disabled);
                spec.solver = SolverKind::TcpDynamic;
                spec
            }
            Scenario::LanDefaultQueue => EngineSpec::paper(
                TestbedSpec::lan_paper(),
                ThrottlePolicy::htcondor_default(),
            ),
            Scenario::LanVpn => {
                EngineSpec::paper(TestbedSpec::lan_vpn_paper(), ThrottlePolicy::Disabled)
            }
            Scenario::LanFairShare => {
                let mut spec =
                    EngineSpec::paper(TestbedSpec::lan_paper(), ThrottlePolicy::Disabled);
                spec.policy = AdmissionConfig::FairShare { limit: 200 };
                // Four competing owners, so the rotation actually matters
                // (the paper's burst came from one benchmark user).
                spec.n_owners = 4;
                spec
            }
            Scenario::LanSharded4 => {
                let mut spec =
                    EngineSpec::paper(TestbedSpec::lan_paper(), ThrottlePolicy::Disabled);
                spec.shadows = 4;
                spec
            }
            Scenario::LanMultiSubmit4 => {
                let mut spec =
                    EngineSpec::paper(TestbedSpec::lan_paper(), ThrottlePolicy::Disabled);
                spec.n_submit_nodes = 4;
                spec.router = RouterPolicy::RoundRobin;
                spec
            }
            Scenario::Hetero25100 => {
                let mut spec =
                    EngineSpec::paper(TestbedSpec::lan_paper(), ThrottlePolicy::Disabled);
                spec.n_submit_nodes = 4;
                spec.testbed.submit_node_gbps = vec![100.0, 100.0, 25.0, 25.0];
                spec.router = RouterPolicy::WeightedByCapacity;
                spec
            }
            Scenario::KillRecover4 => {
                let mut spec =
                    EngineSpec::paper(TestbedSpec::lan_paper(), ThrottlePolicy::Disabled);
                spec.n_submit_nodes = 4;
                spec.router = RouterPolicy::LeastLoaded;
                // Node 1 dies 5 minutes into the ~32-minute burst and
                // returns 10 minutes later; recovered/idle nodes steal
                // queued work beyond a 4-deep imbalance.
                spec.faults = FaultPlan::default()
                    .kill(1, 300.0)
                    .recover(1, 900.0)
                    .with_steal_threshold(4);
                spec
            }
            Scenario::DtnOffload4 => {
                let mut spec =
                    EngineSpec::paper(TestbedSpec::lan_paper(), ThrottlePolicy::Disabled);
                spec.n_data_nodes = 4;
                spec.source = SourcePlan::DedicatedDtn;
                spec
            }
            Scenario::CacheAffine4 => {
                let mut spec =
                    EngineSpec::paper(TestbedSpec::lan_paper(), ThrottlePolicy::Disabled);
                spec.n_data_nodes = 4;
                spec.source = SourcePlan::DedicatedDtn;
                spec.source_selector = SourceSelector::CacheAware;
                // 8 × 2 GB extents over 4 DTNs: each node's cache holds
                // exactly its 2 staged extents, and the bulk store
                // behind the cache is spinning — so a placement-blind
                // selector pays seek-bound cold reads while the
                // cache-aware one streams everything warm.
                spec.n_extents = 8;
                spec.testbed.dtn_cache_bytes = 2 * spec.input_bytes.0;
                spec.testbed.dtn_spinning = true;
                spec
            }
            Scenario::PetascaleWeek3x2 => {
                let mut spec =
                    EngineSpec::paper(TestbedSpec::lan_paper(), ThrottlePolicy::Disabled);
                // 3 sites × (1 submit node + 2 DTNs + 2 workers): the
                // contiguous-block partition puts exactly one submit
                // node, two data nodes and two of lan_paper's six
                // workers in each site.
                spec.testbed.n_sites = 3;
                spec.n_submit_nodes = 3;
                spec.n_data_nodes = 6;
                spec.source = SourcePlan::DedicatedDtn;
                // Round-robin over sites fills every matrix cell — the
                // Petascale benchmark measured all pairs, not just the
                // local diagonal.
                spec.site_selector = SiteSelector::RoundRobin;
                spec.router = RouterPolicy::RoundRobin;
                spec.policy = AdmissionConfig::FairShare { limit: 200 };
                spec.n_owners = 3;
                spec
            }
        }
    }

    /// Paper-reported values for comparison in the report (None where the
    /// paper gives none).
    pub fn paper_sustained_gbps(&self) -> Option<f64> {
        match self {
            Scenario::LanPaper => Some(90.0),
            // Same paper figure either way: both solvers model §IV's WAN.
            Scenario::WanPaper | Scenario::WanTcpDynamic => Some(60.0),
            Scenario::LanDefaultQueue => None,
            Scenario::LanVpn => Some(25.0),
            Scenario::LanFairShare
            | Scenario::LanSharded4
            | Scenario::LanMultiSubmit4
            | Scenario::Hetero25100
            | Scenario::KillRecover4
            | Scenario::DtnOffload4
            | Scenario::CacheAffine4
            | Scenario::PetascaleWeek3x2 => None,
        }
    }

    pub fn paper_makespan_min(&self) -> Option<f64> {
        match self {
            Scenario::LanPaper => Some(32.0),
            Scenario::WanPaper | Scenario::WanTcpDynamic => Some(49.0),
            Scenario::LanDefaultQueue => Some(64.0),
            Scenario::LanVpn => None,
            Scenario::LanFairShare
            | Scenario::LanSharded4
            | Scenario::LanMultiSubmit4
            | Scenario::Hetero25100
            | Scenario::KillRecover4
            | Scenario::DtnOffload4
            | Scenario::CacheAffine4
            | Scenario::PetascaleWeek3x2 => None,
        }
    }
}

/// A runnable experiment (scenario preset or custom spec).
pub struct Experiment {
    pub spec: EngineSpec,
    pub label: String,
}

impl Experiment {
    pub fn scenario(s: Scenario) -> Experiment {
        Experiment {
            spec: s.spec(),
            label: s.name().to_string(),
        }
    }

    pub fn custom(label: &str, spec: EngineSpec) -> Experiment {
        Experiment {
            spec,
            label: label.to_string(),
        }
    }

    /// Scale the workload down by `factor` (jobs and monitor bin) for fast
    /// smoke runs; sustained throughput is unchanged, makespan scales.
    pub fn scaled(mut self, factor: u32) -> Experiment {
        assert!(factor >= 1);
        self.spec.n_jobs = (self.spec.n_jobs / factor).max(1);
        self.label = format!("{}(1/{factor})", self.label);
        self
    }

    /// Override the transfer-admission policy (scenario knob).
    pub fn with_policy(mut self, policy: AdmissionConfig) -> Experiment {
        self.spec.policy = policy;
        self
    }

    /// Override the shadow-pool shard count (scenario knob).
    pub fn with_shadows(mut self, shadows: u32) -> Experiment {
        self.spec.shadows = shadows.max(1);
        self
    }

    /// Override the submit-node count and pool-router strategy
    /// (scenario knob).
    pub fn with_submit_nodes(mut self, nodes: u32, router: RouterPolicy) -> Experiment {
        self.spec.n_submit_nodes = nodes.max(1);
        self.spec.router = router;
        self
    }

    /// Override the data-node fleet size and source plan (scenario
    /// knob).
    pub fn with_data_nodes(mut self, nodes: u32, source: SourcePlan) -> Experiment {
        self.spec.n_data_nodes = nodes;
        self.spec.source = source;
        self
    }

    pub fn run(self) -> Result<Report> {
        let result = Engine::new(self.spec.clone()).run()?;
        Ok(Report::from_engine(self.label, &self.spec, result))
    }
}

/// The numbers the paper quotes, measured from one run.
#[derive(Debug)]
pub struct Report {
    pub label: String,
    pub n_jobs: u32,
    pub makespan: SimTime,
    pub sustained: Gbps,
    pub peak: Gbps,
    pub median_runtime_s: f64,
    /// Median input transfer time as the user log reports it (includes
    /// transfer-queue wait — HTCondor's "input transfer time").
    pub median_input_transfer: SimTime,
    /// Median wire-only transfer time (excludes queue wait).
    pub median_wire_transfer: SimTime,
    pub peak_concurrent_transfers: u32,
    pub negotiation_cycles: u64,
    pub errors: u64,
    /// Admission-policy label driving each node's data mover.
    pub policy: String,
    /// Network-solver label the run's fluid flows were rated with
    /// (`fair-share` / `tcp-dynamic`, the `SOLVER` knob).
    pub solver: String,
    /// Shadow shards across the whole pool (nodes × per-node shards).
    pub shards: usize,
    /// Submit-node count.
    pub n_submit_nodes: usize,
    /// Pool-router strategy label (meaningful when `n_submit_nodes > 1`).
    pub router_policy: String,
    /// Dedicated data-node count (0 = submit-funnel-only pool).
    pub n_data_nodes: usize,
    /// Data-source plan label (`submit-funnel` / `dedicated-dtn` /
    /// `hybrid@<bytes>`).
    pub source_plan: String,
    /// Which-DTN selection-strategy label (`round-robin` /
    /// `cache-aware` / `owner-affinity` / `weighted-by-capacity`).
    pub source_selector: String,
    /// Sites in the federation (1 = unfederated pool).
    pub n_sites: usize,
    /// Which-site selection-strategy label (`local-first` /
    /// `cache-aware` / `round-robin`; only meaningful with
    /// `n_sites > 1`).
    pub site_selector: String,
    /// Site×site goodput matrix: `site_matrix_bytes[src][dst]` is the
    /// input payload bytes served by a site-`src` source (funnel or
    /// DTN) to a site-`dst` worker. Always `n_sites × n_sites`; a 1×1
    /// total on unfederated runs.
    pub site_matrix_bytes: Vec<Vec<u64>>,
    /// DTN storage-cache accounting summed over the fleet: reads served
    /// from page cache vs the (slower) device. (0, 0) with no fleet.
    pub dtn_cache_hits: u64,
    pub dtn_cache_misses: u64,
    /// Aggregate data-mover accounting (per-shard vectors node-major,
    /// spurious completes, failed/recovered-node and work-steal counts).
    pub mover: MoverStats,
    /// Per-submit-node router accounting (routing decisions and bytes).
    pub router: RouterStats,
    /// Per-node fault timeline: every applied `FaultPlan` event with its
    /// planned/applied instants, the transfers it re-admitted and the
    /// bytes the node had served (empty for fault-free runs).
    pub chaos: ChaosTimeline,
    /// Aggregate submit-NIC throughput binned like the paper's
    /// monitoring (5 min).
    pub series_5min: BinSeries,
    /// Finer aggregate series for plots/tests.
    pub series: BinSeries,
    /// Per-submit-node NIC series (index = node, same bin width as
    /// `series`). Aggregation contract: `series` is the element-wise sum
    /// of ALL per-source series — these AND `per_dtn_series` — so
    /// per-source and pool-level plots stay consistent by construction
    /// (`metrics::BinSeries::sum`).
    pub per_node_series: Vec<BinSeries>,
    /// Per-data-node NIC series (index = dtn, same bin width as
    /// `series`; empty with no DTN fleet). Part of the same aggregation
    /// contract as `per_node_series`.
    pub per_dtn_series: Vec<BinSeries>,
}

impl Report {
    fn from_engine(label: String, spec: &EngineSpec, r: EngineResult) -> Report {
        let mut runtime = OnlineStats::new();
        let mut ttransfer = OnlineStats::new();
        let mut twire = OnlineStats::new();
        for j in &r.schedd.jobs {
            if let Some(d) = j.run_duration() {
                runtime.push(d.as_secs_f64());
            }
            if let Some(d) = j.input_transfer_duration() {
                ttransfer.push(d.as_secs_f64());
            }
            if let Some(d) = j.input_wire_duration() {
                twire.push(d.as_secs_f64());
            }
        }
        let five_min = SimTime::from_secs(300);
        let series_5min = if r.monitor.bin_width().0 <= five_min.0
            && five_min.0 % r.monitor.bin_width().0 == 0
        {
            r.monitor.rebin(five_min)
        } else {
            r.monitor.clone()
        };
        Report {
            label,
            n_jobs: spec.n_jobs,
            makespan: r.schedd.makespan().unwrap_or(SimTime::ZERO),
            sustained: r.monitor.sustained_gbps(0.5),
            peak: r.monitor.peak_gbps(),
            median_runtime_s: runtime.median(),
            median_input_transfer: SimTime::from_secs_f64(ttransfer.median().max(0.0)),
            median_wire_transfer: SimTime::from_secs_f64(twire.median().max(0.0)),
            peak_concurrent_transfers: r.peak_concurrent_transfers,
            negotiation_cycles: r.negotiation_cycles,
            errors: r.errors,
            policy: spec.policy.label(),
            solver: spec.solver.label().to_string(),
            shards: r.mover.bytes_per_shard.len(),
            n_submit_nodes: r.monitors.len(),
            router_policy: spec.router.label().to_string(),
            n_data_nodes: r.dtn_monitors.len(),
            source_plan: spec.source.label(),
            source_selector: spec.source_selector.label().to_string(),
            n_sites: r.site_matrix.len().max(1),
            site_selector: spec.site_selector.label().to_string(),
            site_matrix_bytes: r.site_matrix,
            dtn_cache_hits: r.dtn_cache_hits,
            dtn_cache_misses: r.dtn_cache_misses,
            mover: r.mover,
            router: r.router,
            chaos: r.chaos,
            series_5min,
            series: r.monitor,
            per_node_series: r.monitors,
            per_dtn_series: r.dtn_monitors,
        }
    }

    pub fn sustained_gbps(&self) -> f64 {
        self.sustained.0
    }

    /// One row of the paper-vs-measured comparison table.
    pub fn table_row(&self, paper_gbps: Option<f64>, paper_makespan_min: Option<f64>) -> String {
        let fmt_opt = |o: Option<f64>| o.map(|v| format!("{v:.0}")).unwrap_or_else(|| "-".into());
        format!(
            "{:<16} {:>6} jobs | sustained {:>6.1} Gbps (paper {:>4}) | makespan {:>6.1} min (paper {:>4}) | median xfer {:>5.1} min | median run {:>4.1} s | errors {}",
            self.label,
            self.n_jobs,
            self.sustained.0,
            fmt_opt(paper_gbps),
            self.makespan.as_mins_f64(),
            fmt_opt(paper_makespan_min),
            self.median_input_transfer.as_mins_f64(),
            self.median_runtime_s,
            self.errors,
        )
    }

    /// Render the Fig. 1/2-style ASCII monitor chart.
    pub fn figure(&self, cap_gbps: f64) -> String {
        self.series_5min.ascii_chart(48, Gbps(cap_gbps))
    }

    /// Bytes that crossed the WAN: every off-diagonal cell of the
    /// site×site matrix (0 on unfederated runs).
    pub fn cross_site_bytes(&self) -> u64 {
        self.site_matrix_bytes
            .iter()
            .enumerate()
            .flat_map(|(s, row)| {
                row.iter()
                    .enumerate()
                    .filter(move |(d, _)| *d != s)
                    .map(|(_, b)| *b)
            })
            .sum()
    }

    /// The site×site goodput matrix as JSON (the `site_matrix` object
    /// documented in docs/REPORTS.md) — what the `wan_federation` bench
    /// writes under `BENCH_REPORT_DIR`.
    pub fn site_matrix_json(&self) -> String {
        let rows: Vec<String> = self
            .site_matrix_bytes
            .iter()
            .map(|row| {
                let cells: Vec<String> = row.iter().map(|b| b.to_string()).collect();
                format!("[{}]", cells.join(","))
            })
            .collect();
        format!(
            "{{\"n_sites\":{},\"site_selector\":\"{}\",\"matrix_bytes\":[{}],\"cross_site_bytes\":{}}}",
            self.n_sites,
            self.site_selector,
            rows.join(","),
            self.cross_site_bytes()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::Bytes;

    #[test]
    fn scenario_specs_match_paper_setup() {
        let lan = Scenario::LanPaper.spec();
        assert_eq!(lan.n_jobs, 10_000);
        assert_eq!(lan.input_bytes, Bytes(2_000_000_000));
        assert_eq!(lan.testbed.total_slots(), 200);
        assert_eq!(
            lan.policy,
            AdmissionConfig::from(ThrottlePolicy::Disabled)
        );
        assert_eq!(lan.shadows, 1, "the paper's single-funnel submit node");

        let wan = Scenario::WanPaper.spec();
        assert!(wan.testbed.wan.is_some());
        assert_eq!(wan.testbed.total_slots(), 200);
        assert_eq!(wan.solver, SolverKind::FairShare, "steady-state default");

        let wt = Scenario::WanTcpDynamic.spec();
        assert_eq!(wt.solver, SolverKind::TcpDynamic);
        assert!(wt.testbed.wan.is_some(), "same WAN topology as fig2-wan");
        assert_eq!(wt.n_jobs, wan.n_jobs, "same workload as fig2-wan");

        let q = Scenario::LanDefaultQueue.spec();
        assert_ne!(q.policy, AdmissionConfig::from(ThrottlePolicy::Disabled));

        let v = Scenario::LanVpn.spec();
        assert!(v.testbed.vpn_on_submit);

        let fs = Scenario::LanFairShare.spec();
        assert_eq!(fs.policy, AdmissionConfig::FairShare { limit: 200 });
        assert_eq!(fs.n_owners, 4, "fair-share needs competing owners");

        let sh = Scenario::LanSharded4.spec();
        assert_eq!(sh.shadows, 4);

        let ms = Scenario::LanMultiSubmit4.spec();
        assert_eq!(ms.n_submit_nodes, 4);
        assert_eq!(ms.router, RouterPolicy::RoundRobin);
        assert_eq!(ms.shadows, 1, "per-node pools stay single-shard");

        let het = Scenario::Hetero25100.spec();
        assert_eq!(het.n_submit_nodes, 4);
        assert_eq!(het.testbed.submit_node_gbps, vec![100.0, 100.0, 25.0, 25.0]);
        assert_eq!(het.router, RouterPolicy::WeightedByCapacity);

        let kr = Scenario::KillRecover4.spec();
        assert_eq!(kr.n_submit_nodes, 4);
        assert_eq!(kr.faults.events.len(), 2);
        assert_eq!(kr.faults.steal_threshold, Some(4));
        assert!(kr.faults.validate(4, 0, 1).is_ok());

        let dtn = Scenario::DtnOffload4.spec();
        assert_eq!(dtn.n_data_nodes, 4);
        assert_eq!(dtn.source, SourcePlan::DedicatedDtn);
        assert_eq!(dtn.n_submit_nodes, 1, "scheduling stays on one node");

        let ca = Scenario::CacheAffine4.spec();
        assert_eq!(ca.n_data_nodes, 4);
        assert_eq!(ca.source_selector, SourceSelector::CacheAware);
        assert_eq!(ca.n_extents, 8);
        assert!(ca.testbed.dtn_spinning, "cold reads must hurt");
        assert_eq!(
            ca.testbed.dtn_cache_bytes,
            2 * ca.input_bytes.0,
            "each node caches exactly its 2 staged extents"
        );

        let pw = Scenario::PetascaleWeek3x2.spec();
        assert_eq!(pw.testbed.n_sites, 3);
        assert_eq!(pw.n_submit_nodes, 3, "one submit node per site");
        assert_eq!(pw.n_data_nodes, 6, "two DTNs per site");
        assert_eq!(pw.testbed.workers.len(), 6, "two worker hosts per site");
        assert_eq!(pw.source, SourcePlan::DedicatedDtn);
        assert_eq!(pw.site_selector, SiteSelector::RoundRobin);
        assert_eq!(pw.policy, AdmissionConfig::FairShare { limit: 200 });
        assert_eq!(pw.n_owners, 3, "one benchmark owner per site");
    }

    /// The tentpole calibration: on a warm-extent burst (every extent
    /// staged hot on exactly one data node), the cache-aware selector
    /// reads everything from page cache and measurably beats blind
    /// round-robin — which keeps landing transfers on nodes whose
    /// spinning bulk store has to serve them cold.
    #[test]
    fn cache_aware_selector_beats_round_robin_on_warm_extents() {
        let shrink = |selector: SourceSelector| {
            let mut spec = Scenario::CacheAffine4.spec();
            spec.n_jobs = 48;
            spec.input_bytes = Bytes(200_000_000);
            spec.testbed.dtn_cache_bytes = 2 * spec.input_bytes.0;
            spec.runtime_median_s = 0.5;
            spec.testbed.monitor_bin = SimTime::from_secs(5);
            spec.testbed.workers.truncate(2);
            spec.testbed.workers[0].slots = 4;
            spec.testbed.workers[1].slots = 4;
            spec.source_selector = selector;
            spec
        };
        let cache = Experiment::custom("cache-affine", shrink(SourceSelector::CacheAware))
            .run()
            .unwrap();
        let rr = Experiment::custom("cache-blind-rr", shrink(SourceSelector::RoundRobin))
            .run()
            .unwrap();
        assert_eq!(cache.errors, 0);
        assert_eq!(rr.errors, 0);
        assert_eq!(cache.source_selector, "cache-aware");

        // The steering is what differs: cache-aware never touches a
        // device, round-robin mostly does.
        assert_eq!(
            cache.dtn_cache_misses, 0,
            "warm burst fully cache-served ({} hits)",
            cache.dtn_cache_hits
        );
        assert!(
            rr.dtn_cache_misses > rr.dtn_cache_hits,
            "blind rotation should miss more than it hits: {} miss / {} hit",
            rr.dtn_cache_misses,
            rr.dtn_cache_hits
        );
        // And the steering pays: strictly lower makespan (by a real
        // margin) at strictly higher aggregate goodput.
        assert!(
            cache.makespan.as_secs_f64() * 1.1 <= rr.makespan.as_secs_f64(),
            "cache-aware {} not measurably faster than round-robin {}",
            cache.makespan,
            rr.makespan
        );
        assert!(
            cache.sustained_gbps() > rr.sustained_gbps(),
            "cache-aware goodput {} <= round-robin {}",
            cache.sustained_gbps(),
            rr.sustained_gbps()
        );
    }

    /// The tentpole acceptance experiment: with 4 DTNs serving the
    /// bytes, the submit-node NIC carries <10% of what it carries under
    /// the funnel baseline, at equal aggregate goodput.
    #[test]
    fn dtn_offload_keeps_submit_nic_near_idle_at_equal_goodput() {
        let shrink = |mut spec: EngineSpec| {
            spec.n_jobs = 60;
            spec.input_bytes = Bytes(200_000_000);
            spec.testbed.monitor_bin = SimTime::from_secs(5);
            spec
        };
        let funnel = Experiment::custom("funnel-baseline", shrink(Scenario::LanPaper.spec()))
            .run()
            .unwrap();
        let offload = Experiment::custom("dtn-offload", shrink(Scenario::DtnOffload4.spec()))
            .run()
            .unwrap();
        assert_eq!(funnel.errors, 0);
        assert_eq!(offload.errors, 0);

        let submit_bytes = |r: &Report| -> f64 {
            r.per_node_series.iter().map(|s| s.total_bytes()).sum()
        };
        let funnel_submit = submit_bytes(&funnel);
        let offload_submit = submit_bytes(&offload);
        assert!(funnel_submit > 0.0);
        assert!(
            offload_submit < 0.10 * funnel_submit,
            "submit NIC still hot under DTN offload: {offload_submit} vs funnel {funnel_submit}"
        );
        // The DTN fleet carried the burst instead...
        let dtn_bytes: f64 = offload.per_dtn_series.iter().map(|s| s.total_bytes()).sum();
        assert!(dtn_bytes >= 60.0 * 200_000_000.0);
        // ...at matching aggregate goodput.
        assert!(
            offload.sustained_gbps() >= 0.9 * funnel.sustained_gbps(),
            "offload goodput {} dropped vs funnel {}",
            offload.sustained_gbps(),
            funnel.sustained_gbps()
        );
        assert!(
            offload.makespan.as_secs_f64() <= funnel.makespan.as_secs_f64() * 1.1,
            "offload makespan {} regressed vs funnel {}",
            offload.makespan,
            funnel.makespan
        );
        // Per-source aggregation contract holds with a DTN fleet.
        let mut all = offload.per_node_series.clone();
        all.extend(offload.per_dtn_series.iter().cloned());
        let summed = BinSeries::sum(&all);
        let agg = offload.series.bins();
        let per = summed.bins();
        assert_eq!(agg.len(), per.len());
        for ((_, a), (_, b)) in agg.iter().zip(per.iter()) {
            assert!((a - b).abs() < 1e-6, "bin mismatch: {a} vs {b}");
        }
        assert_eq!(offload.n_data_nodes, 4);
        assert_eq!(offload.source_plan, "dedicated-dtn");
    }

    /// ROADMAP calibration: on the mixed 25/100 Gbps fleet, routing
    /// weighted by NIC capacity must beat round-robin's makespan —
    /// round-robin drowns the 25 Gbps nodes in a burst their NICs can't
    /// drain at full stream rate.
    #[test]
    fn hetero_weighted_beats_round_robin_makespan() {
        let base = |router: RouterPolicy| {
            let mut spec = Scenario::Hetero25100.spec();
            // 200 simultaneous 200 MB transfers: under round-robin each
            // 25 Gbps node carries 50 × 1.1 Gbps streams — 2.4× its NIC —
            // while weighted 4:1 routing keeps every NIC under its rate.
            spec.n_jobs = 200;
            spec.input_bytes = Bytes(200_000_000);
            spec.runtime_median_s = 0.6;
            spec.testbed.monitor_bin = SimTime::from_secs(5);
            spec.router = router;
            spec
        };
        let weighted = Experiment::custom("hetero-weighted", base(RouterPolicy::WeightedByCapacity))
            .run()
            .unwrap();
        let rr = Experiment::custom("hetero-rr", base(RouterPolicy::RoundRobin))
            .run()
            .unwrap();
        assert_eq!(weighted.errors, 0);
        assert_eq!(rr.errors, 0);
        assert_eq!(weighted.mover.total_admitted, 200);
        // 4:1 deficit round-robin: 80/80/20/20.
        assert_eq!(weighted.router.routed_per_node, vec![80, 80, 20, 20]);
        assert_eq!(rr.router.routed_per_node, vec![50, 50, 50, 50]);
        assert!(
            weighted.makespan < rr.makespan,
            "weighted {} !< round-robin {}",
            weighted.makespan,
            rr.makespan
        );
    }

    #[test]
    fn scaled_reduces_jobs() {
        let e = Experiment::scenario(Scenario::LanPaper).scaled(100);
        assert_eq!(e.spec.n_jobs, 100);
        assert!(e.label.contains("1/100"));
    }

    #[test]
    fn knob_helpers_override_policy_and_shadows() {
        let e = Experiment::scenario(Scenario::LanPaper)
            .with_policy(AdmissionConfig::WeightedBySize { limit: 50 })
            .with_shadows(8);
        assert_eq!(e.spec.policy, AdmissionConfig::WeightedBySize { limit: 50 });
        assert_eq!(e.spec.shadows, 8);
        let clamped = Experiment::scenario(Scenario::LanPaper).with_shadows(0);
        assert_eq!(clamped.spec.shadows, 1);
        let routed = Experiment::scenario(Scenario::LanPaper)
            .with_submit_nodes(4, RouterPolicy::OwnerAffinity);
        assert_eq!(routed.spec.n_submit_nodes, 4);
        assert_eq!(routed.spec.router, RouterPolicy::OwnerAffinity);
        let sourced = Experiment::scenario(Scenario::LanPaper)
            .with_data_nodes(2, SourcePlan::Hybrid { threshold: 1 << 20 });
        assert_eq!(sourced.spec.n_data_nodes, 2);
        assert_eq!(
            sourced.spec.source,
            SourcePlan::Hybrid { threshold: 1 << 20 }
        );
    }

    #[test]
    fn report_carries_mover_accounting() {
        let mut spec = Scenario::LanSharded4.spec();
        spec.n_jobs = 40;
        spec.input_bytes = Bytes(50_000_000);
        spec.testbed.monitor_bin = SimTime::from_secs(5);
        let report = Experiment::custom("sharded-smoke", spec).run().unwrap();
        assert_eq!(report.shards, 4);
        assert_eq!(report.policy, "fifo/disabled");
        assert_eq!(report.solver, "fair-share", "default solver stamped");
        assert_eq!(report.mover.total_admitted, 40);
        assert_eq!(report.mover.released_without_active, 0);
        let routed: u64 = report.mover.bytes_per_shard.iter().sum();
        assert_eq!(routed, 40 * 50_000_000);
    }

    #[test]
    fn multi_submit_report_series_are_consistent() {
        let mut spec = Scenario::LanMultiSubmit4.spec();
        spec.n_jobs = 40;
        spec.input_bytes = Bytes(50_000_000);
        spec.testbed.monitor_bin = SimTime::from_secs(5);
        let report = Experiment::custom("multi-submit-smoke", spec).run().unwrap();
        assert_eq!(report.n_submit_nodes, 4);
        assert_eq!(report.router_policy, "round-robin");
        assert_eq!(report.per_node_series.len(), 4);
        // The aggregation contract: per-node series sum to the aggregate,
        // bin by bin.
        let summed = BinSeries::sum(&report.per_node_series);
        let agg = report.series.bins();
        let per = summed.bins();
        assert_eq!(agg.len(), per.len());
        for ((_, a), (_, b)) in agg.iter().zip(per.iter()) {
            assert!((a - b).abs() < 1e-6, "bin mismatch: {a} vs {b}");
        }
        assert_eq!(report.router.routed_per_node.iter().sum::<u64>(), 40);
    }

    /// The federated scenario's report carries the full site×site
    /// goodput matrix: every site sources bytes (round-robin site
    /// selection), every site receives bytes (more jobs than slots, so
    /// all six workers run), cells sum to the burst's payload bytes,
    /// and the JSON rendering round-trips the shape.
    #[test]
    fn petascale_report_carries_the_site_matrix() {
        let mut spec = Scenario::PetascaleWeek3x2.spec();
        spec.n_jobs = 54;
        spec.input_bytes = Bytes(50_000_000);
        spec.testbed.monitor_bin = SimTime::from_secs(5);
        // 4 slots per worker: 54 jobs over 24 slots keeps every worker
        // (so every destination site) busy.
        for w in spec.testbed.workers.iter_mut() {
            w.slots = 4;
        }
        let report = Experiment::custom("petascale-smoke", spec).run().unwrap();
        assert_eq!(report.errors, 0);
        assert_eq!(report.n_sites, 3);
        assert_eq!(report.site_selector, "round-robin");
        assert_eq!(report.site_matrix_bytes.len(), 3);
        assert!(report.site_matrix_bytes.iter().all(|row| row.len() == 3));
        let total: u64 = report.site_matrix_bytes.iter().flatten().sum();
        assert_eq!(total, 54 * 50_000_000, "every input byte lands in a cell");
        for s in 0..3 {
            let row: u64 = report.site_matrix_bytes[s].iter().sum();
            assert!(row > 0, "site {s} sourced nothing under round-robin");
            let col: u64 = report.site_matrix_bytes.iter().map(|r| r[s]).sum();
            assert!(col > 0, "site {s} received nothing");
        }
        assert!(report.cross_site_bytes() > 0, "round-robin must cross the WAN");
        assert!(report.cross_site_bytes() < total, "diagonal carries bytes too");
        let json = report.site_matrix_json();
        assert!(json.contains("\"n_sites\":3"));
        assert!(json.contains("\"site_selector\":\"round-robin\""));
        assert!(json.contains(&format!(
            "\"cross_site_bytes\":{}",
            report.cross_site_bytes()
        )));
    }

    /// Unfederated reports collapse to a 1×1 matrix holding the whole
    /// burst — no site machinery leaks into single-site runs.
    #[test]
    fn unfederated_report_has_one_by_one_matrix() {
        let mut spec = Scenario::LanPaper.spec();
        spec.n_jobs = 20;
        spec.input_bytes = Bytes(50_000_000);
        spec.testbed.monitor_bin = SimTime::from_secs(5);
        let report = Experiment::custom("single-site", spec).run().unwrap();
        assert_eq!(report.n_sites, 1);
        assert_eq!(report.site_matrix_bytes, vec![vec![20 * 50_000_000u64]]);
        assert_eq!(report.cross_site_bytes(), 0);
    }

    #[test]
    fn small_report_has_sane_numbers() {
        let mut spec = Scenario::LanPaper.spec();
        spec.n_jobs = 60;
        spec.input_bytes = Bytes(200_000_000);
        spec.testbed.monitor_bin = SimTime::from_secs(5);
        let report = Experiment::custom("smoke", spec).run().unwrap();
        assert_eq!(report.errors, 0);
        assert!(report.sustained_gbps() > 0.0);
        assert!(report.makespan > SimTime::ZERO);
        assert!(report.median_runtime_s > 0.5);
        let row = report.table_row(Some(90.0), Some(32.0));
        assert!(row.contains("smoke"));
        assert!(row.contains("paper"));
        let fig = report.figure(100.0);
        assert!(fig.contains("Gbps"));
    }
}
