//! The coordinator: ties daemons, transfer subsystem, storage and network
//! into a runnable pool.
//!
//! * [`engine`] — the virtual-time experiment engine (paper-scale runs:
//!   20 TB of traffic in seconds of wall time).
//! * [`experiment`] — scenario presets for every figure/table in the
//!   paper, and the report type benches print.

pub mod engine;
pub mod experiment;

pub use experiment::{Experiment, Report, Scenario};
