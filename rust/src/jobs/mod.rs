//! Job system: submit descriptions, job ClassAds, lifecycle state machine
//! and the condor-style user event log.
//!
//! A submit description is the HTCondor submit-file dialect the paper's
//! test used — one transaction queueing 10k jobs, each with a unique input
//! file:
//!
//! ```text
//! executable = validate.sh
//! transfer_input_files = input_$(Process)
//! request_memory = 128
//! queue 10000
//! ```

pub mod log;
pub mod submit;

use crate::classad::Ad;
use crate::util::units::{Bytes, SimTime};

/// Pool-unique job identifier (cluster.proc, as in HTCondor).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId {
    pub cluster: u32,
    pub proc: u32,
}

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}.{}", self.cluster, self.proc)
    }
}

/// Job lifecycle states (the subset of HTCondor's that data movement
/// exercises).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// In the queue, unmatched.
    Idle,
    /// Matched to a slot; input sandbox waiting in the transfer queue.
    TransferQueued,
    /// Input sandbox streaming to the worker.
    TransferringInput,
    /// Executing on the worker.
    Running,
    /// Output sandbox streaming back.
    TransferringOutput,
    /// Done; left the queue.
    Completed,
    /// Held after an error.
    Held,
}

/// Everything the engine needs to move and run one job.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub id: JobId,
    pub owner: String,
    /// Input sandbox file name (resolved in the submit node's storage).
    pub input_file: String,
    /// Physical extent behind `input_file` (hard-linked names share one
    /// extent — the paper's §III dataset). Cache-aware source selection
    /// uses it to route the transfer to the data node already holding
    /// the extent hot; `None` = unknown.
    pub input_extent: Option<crate::storage::ExtentId>,
    pub input_bytes: Bytes,
    pub output_bytes: Bytes,
    /// Requested wall time of the payload (sampled at run time around
    /// this median — the paper's validation script ran ~5 s).
    pub runtime_median_s: f64,
}

/// A job in the schedd queue: spec + mutable lifecycle record.
#[derive(Debug, Clone)]
pub struct Job {
    pub spec: JobSpec,
    pub state: JobState,
    pub ad: Ad,
    /// Timestamps for the report (all virtual time).
    pub t_submitted: SimTime,
    pub t_matched: Option<SimTime>,
    pub t_transfer_queued: Option<SimTime>,
    pub t_input_started: Option<SimTime>,
    pub t_input_done: Option<SimTime>,
    pub t_run_done: Option<SimTime>,
    pub t_completed: Option<SimTime>,
}

impl Job {
    pub fn new(spec: JobSpec, submitted: SimTime) -> Job {
        let ad = build_job_ad(&spec);
        Job {
            spec,
            state: JobState::Idle,
            ad,
            t_submitted: submitted,
            t_matched: None,
            t_transfer_queued: None,
            t_input_started: None,
            t_input_done: None,
            t_run_done: None,
            t_completed: None,
        }
    }

    /// Input transfer duration as the user log reports it: from entering
    /// the transfer queue to transfer completion (includes queue wait).
    pub fn input_transfer_duration(&self) -> Option<SimTime> {
        Some(self.t_input_done?.since(self.t_transfer_queued?))
    }

    /// Wire-only input transfer duration (excludes queue wait).
    pub fn input_wire_duration(&self) -> Option<SimTime> {
        Some(self.t_input_done?.since(self.t_input_started?))
    }

    pub fn run_duration(&self) -> Option<SimTime> {
        Some(self.t_run_done?.since(self.t_input_done?))
    }
}

/// Build the job's ClassAd (what the schedd sends to the negotiator).
pub fn build_job_ad(spec: &JobSpec) -> Ad {
    let mut ad = Ad::new("Job");
    ad.insert("ClusterId", spec.id.cluster as i64);
    ad.insert("ProcId", spec.id.proc as i64);
    ad.insert("Owner", spec.owner.as_str());
    ad.insert("TransferInput", spec.input_file.as_str());
    ad.insert("TransferInputSizeMB", (spec.input_bytes.0 / 1_000_000) as i64);
    ad.insert("RequestCpus", 1i64);
    ad.insert("RequestMemory", 128i64);
    ad.insert_expr(
        "Requirements",
        "TARGET.HasFileTransfer && TARGET.Cpus >= MY.RequestCpus \
         && TARGET.Memory >= MY.RequestMemory",
    )
    .expect("static requirements parse");
    ad
}

/// Signature used for autoclustering: jobs whose matchmaking-relevant
/// attributes are identical share one autocluster and are matched once per
/// negotiation cycle (HTCondor's optimization, essential at 10k jobs).
pub fn autocluster_signature(ad: &Ad) -> String {
    let mut sig = String::new();
    for attr in ["requirements", "requestcpus", "requestmemory", "rank"] {
        if let Some(e) = ad.get_expr(attr) {
            sig.push_str(&format!("{attr}={e};"));
        }
    }
    sig
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(proc_: u32) -> JobSpec {
        JobSpec {
            id: JobId {
                cluster: 1,
                proc: proc_,
            },
            owner: "alice".into(),
            input_file: format!("input_{proc_}"),
            input_extent: None,
            input_bytes: Bytes::gib(2),
            output_bytes: Bytes::kib(4),
            runtime_median_s: 5.0,
        }
    }

    #[test]
    fn job_ad_matches_capable_slot() {
        let job = Job::new(spec(0), SimTime::ZERO);
        let mut slot = Ad::new("Machine");
        slot.insert("HasFileTransfer", true);
        slot.insert("Cpus", 8i64);
        slot.insert("Memory", 16384i64);
        assert!(crate::classad::matches(&job.ad, &slot).unwrap());
    }

    #[test]
    fn job_ad_rejects_incapable_slot() {
        let job = Job::new(spec(0), SimTime::ZERO);
        let mut slot = Ad::new("Machine");
        slot.insert("HasFileTransfer", false);
        slot.insert("Cpus", 8i64);
        slot.insert("Memory", 16384i64);
        assert!(!crate::classad::matches(&job.ad, &slot).unwrap());
    }

    #[test]
    fn autocluster_groups_identical_jobs() {
        let a = Job::new(spec(0), SimTime::ZERO);
        let b = Job::new(spec(1), SimTime::ZERO);
        assert_eq!(
            autocluster_signature(&a.ad),
            autocluster_signature(&b.ad),
            "same requirements → same autocluster"
        );
        let mut c = Job::new(spec(2), SimTime::ZERO);
        c.ad.insert_expr("Rank", "TARGET.KFlops").unwrap();
        assert_ne!(autocluster_signature(&a.ad), autocluster_signature(&c.ad));
    }

    #[test]
    fn transfer_durations() {
        let mut j = Job::new(spec(0), SimTime::ZERO);
        assert!(j.input_transfer_duration().is_none());
        j.t_transfer_queued = Some(SimTime::from_secs(10));
        j.t_input_started = Some(SimTime::from_secs(70));
        j.t_input_done = Some(SimTime::from_secs(100));
        assert_eq!(j.input_transfer_duration(), Some(SimTime::from_secs(90)));
        assert_eq!(j.input_wire_duration(), Some(SimTime::from_secs(30)));
    }

    #[test]
    fn jobid_display() {
        assert_eq!(JobId { cluster: 12, proc: 3 }.to_string(), "12.3");
    }
}
