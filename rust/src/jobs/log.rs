//! Condor-style user event log: the flat-file event stream users tail to
//! watch their jobs (`000 Job submitted`, `040 Started transferring input
//! files`, …). The experiment reports are computed from these events, just
//! as the paper read its numbers from HTCondor logs.

use super::JobId;
use crate::util::units::SimTime;
use std::fmt;

/// Event codes follow HTCondor's userlog numbering where one exists.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    Submitted,           // 000
    Executing,           // 001
    Terminated,          // 005
    TransferInputQueued,  // 040 (transfer queued)
    TransferInputBegan,   // 040 (started)
    TransferInputDone,    // 040 (finished)
    TransferInputAborted, // 040 (node failure; transfer re-queued)
    TransferOutputBegan, // 040
    TransferOutputDone,  // 040
    Held,                // 012
}

impl EventKind {
    pub fn code(&self) -> u16 {
        match self {
            EventKind::Submitted => 0,
            EventKind::Executing => 1,
            EventKind::Terminated => 5,
            EventKind::Held => 12,
            _ => 40,
        }
    }

    pub fn describe(&self) -> &'static str {
        match self {
            EventKind::Submitted => "Job submitted",
            EventKind::Executing => "Job executing",
            EventKind::Terminated => "Job terminated",
            EventKind::TransferInputQueued => "Transfer queued: input files",
            EventKind::TransferInputBegan => "Started transferring input files",
            EventKind::TransferInputDone => "Finished transferring input files",
            EventKind::TransferInputAborted => {
                "Input transfer aborted (submit node failed); re-queued"
            }
            EventKind::TransferOutputBegan => "Started transferring output files",
            EventKind::TransferOutputDone => "Finished transferring output files",
            EventKind::Held => "Job was held",
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub t: SimTime,
    pub job: JobId,
    pub kind: EventKind,
}

impl fmt::Display for Event {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{:03} ({}) t+{:.1}s {}",
            self.kind.code(),
            self.job,
            self.t.as_secs_f64(),
            self.kind.describe()
        )
    }
}

/// An append-only in-memory user log (dumpable to text).
#[derive(Debug, Default)]
pub struct UserLog {
    events: Vec<Event>,
}

impl UserLog {
    pub fn new() -> UserLog {
        UserLog::default()
    }

    pub fn record(&mut self, t: SimTime, job: JobId, kind: EventKind) {
        self.events.push(Event { t, job, kind });
    }

    pub fn events(&self) -> &[Event] {
        &self.events
    }

    pub fn count(&self, kind: EventKind) -> usize {
        self.events.iter().filter(|e| e.kind == kind).count()
    }

    /// Events of one job, in order.
    pub fn job_events(&self, job: JobId) -> Vec<Event> {
        self.events.iter().copied().filter(|e| e.job == job).collect()
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        for e in &self.events {
            s.push_str(&e.to_string());
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn jid(p: u32) -> JobId {
        JobId { cluster: 1, proc: p }
    }

    #[test]
    fn records_and_counts() {
        let mut log = UserLog::new();
        log.record(SimTime::ZERO, jid(0), EventKind::Submitted);
        log.record(SimTime::from_secs(1), jid(1), EventKind::Submitted);
        log.record(SimTime::from_secs(2), jid(0), EventKind::Executing);
        assert_eq!(log.count(EventKind::Submitted), 2);
        assert_eq!(log.count(EventKind::Executing), 1);
        assert_eq!(log.job_events(jid(0)).len(), 2);
    }

    #[test]
    fn event_ordering_preserved() {
        let mut log = UserLog::new();
        for k in [
            EventKind::Submitted,
            EventKind::TransferInputQueued,
            EventKind::TransferInputBegan,
            EventKind::TransferInputDone,
            EventKind::Executing,
            EventKind::Terminated,
        ] {
            log.record(SimTime::ZERO, jid(0), k);
        }
        let evs = log.job_events(jid(0));
        assert_eq!(evs.first().unwrap().kind, EventKind::Submitted);
        assert_eq!(evs.last().unwrap().kind, EventKind::Terminated);
    }

    #[test]
    fn render_format() {
        let mut log = UserLog::new();
        log.record(SimTime::from_secs(90), jid(3), EventKind::Terminated);
        let text = log.render();
        assert!(text.contains("005"));
        assert!(text.contains("(1.3)"));
        assert!(text.contains("Job terminated"));
    }

    #[test]
    fn codes_match_htcondor() {
        assert_eq!(EventKind::Submitted.code(), 0);
        assert_eq!(EventKind::Executing.code(), 1);
        assert_eq!(EventKind::Terminated.code(), 5);
        assert_eq!(EventKind::Held.code(), 12);
        assert_eq!(EventKind::TransferInputDone.code(), 40);
    }
}
