//! Submit-description parser: the HTCondor submit-file dialect.
//!
//! Supports the commands the paper's workload uses: `executable`,
//! `transfer_input_files`, `transfer_output_files`, `request_*`,
//! `$(Process)` macro expansion, `+Attr = value` custom attributes, and
//! `queue N` — one transaction creating N procs (the paper queued 10k in a
//! single transaction).

use super::{JobId, JobSpec};
use crate::config::parse_bytes;
use crate::util::units::Bytes;

#[derive(Debug)]
pub enum SubmitError {
    Parse(usize, String),
    Missing(&'static str),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Parse(line, msg) => write!(f, "line {line}: {msg}"),
            SubmitError::Missing(cmd) => write!(f, "missing required command: {cmd}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// A parsed submit description (before `queue` expansion).
#[derive(Debug, Clone, Default)]
pub struct SubmitDescription {
    pub executable: String,
    pub owner: String,
    pub transfer_input_files: String,
    pub input_size: Option<Bytes>,
    pub output_size: Option<Bytes>,
    pub runtime_median_s: f64,
    pub count: u32,
}

/// Parse a submit file and expand `queue N` into job specs for `cluster`.
pub fn parse_submit(text: &str, cluster: u32) -> Result<Vec<JobSpec>, SubmitError> {
    let mut d = SubmitDescription {
        owner: "user".into(),
        runtime_median_s: 5.0,
        count: 0,
        ..Default::default()
    };
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let lower = line.to_ascii_lowercase();
        if lower == "queue" {
            d.count = 1;
            continue;
        }
        if let Some(n) = lower.strip_prefix("queue ") {
            d.count = n
                .trim()
                .parse()
                .map_err(|_| SubmitError::Parse(ln + 1, format!("bad queue count '{n}'")))?;
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| SubmitError::Parse(ln + 1, format!("expected key = value: '{line}'")))?;
        let key = k.trim().to_ascii_lowercase();
        let val = v.trim().to_string();
        match key.as_str() {
            "executable" => d.executable = val,
            "owner" | "accounting_group_user" => d.owner = val,
            "transfer_input_files" => d.transfer_input_files = val,
            "input_size" => {
                d.input_size = Some(Bytes(parse_bytes(&val).ok_or_else(|| {
                    SubmitError::Parse(ln + 1, format!("bad input_size '{val}'"))
                })?))
            }
            "output_size" => {
                d.output_size = Some(Bytes(parse_bytes(&val).ok_or_else(|| {
                    SubmitError::Parse(ln + 1, format!("bad output_size '{val}'"))
                })?))
            }
            "runtime_median" => {
                d.runtime_median_s = val.parse().map_err(|_| {
                    SubmitError::Parse(ln + 1, format!("bad runtime_median '{val}'"))
                })?
            }
            // Accepted-but-ignored standard commands keep real submit
            // files working.
            "universe" | "log" | "output" | "error" | "request_cpus" | "request_memory"
            | "request_disk" | "should_transfer_files" | "when_to_transfer_output"
            | "arguments" => {}
            _ if key.starts_with('+') => {}
            _ => {
                return Err(SubmitError::Parse(
                    ln + 1,
                    format!("unknown submit command '{key}'"),
                ))
            }
        }
    }
    if d.executable.is_empty() {
        return Err(SubmitError::Missing("executable"));
    }
    if d.count == 0 {
        return Err(SubmitError::Missing("queue"));
    }
    Ok(expand(&d, cluster))
}

/// Expand a description into per-proc specs with `$(Process)` substitution.
pub fn expand(d: &SubmitDescription, cluster: u32) -> Vec<JobSpec> {
    (0..d.count)
        .map(|proc_| JobSpec {
            id: JobId { cluster, proc: proc_ },
            owner: d.owner.clone(),
            input_file: substitute(&d.transfer_input_files, proc_, cluster),
            input_extent: None,
            input_bytes: d.input_size.unwrap_or(Bytes::gib(2)),
            output_bytes: d.output_size.unwrap_or(Bytes::kib(4)),
            runtime_median_s: d.runtime_median_s,
        })
        .collect()
}

/// `$(Process)` / `$(Cluster)` macro substitution (case-insensitive).
pub fn substitute(template: &str, proc_: u32, cluster: u32) -> String {
    let mut out = String::with_capacity(template.len() + 8);
    let mut rest = template;
    while let Some(start) = rest.find("$(") {
        out.push_str(&rest[..start]);
        let after = &rest[start + 2..];
        match after.find(')') {
            None => {
                out.push_str(&rest[start..]);
                return out;
            }
            Some(end) => {
                let name = after[..end].to_ascii_lowercase();
                match name.as_str() {
                    "process" | "procid" => out.push_str(&proc_.to_string()),
                    "cluster" | "clusterid" => out.push_str(&cluster.to_string()),
                    _ => {} // unknown macros expand empty, like condor_submit
                }
                rest = &after[end + 1..];
            }
        }
    }
    out.push_str(rest);
    out
}

/// The paper's §III submit file: 10k jobs, 2 GB unique inputs, a trivial
/// validation script.
pub fn paper_submit_text(jobs: u32) -> String {
    format!(
        "# eScience'21 HTCondor 100 Gbps benchmark workload\n\
         executable = validate.sh\n\
         owner = benchmark\n\
         transfer_input_files = input_$(Process)\n\
         input_size = 2GB\n\
         output_size = 4KB\n\
         runtime_median = 5\n\
         should_transfer_files = YES\n\
         queue {jobs}\n"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_paper_submit() {
        let specs = parse_submit(&paper_submit_text(10_000), 1).unwrap();
        assert_eq!(specs.len(), 10_000);
        assert_eq!(specs[0].input_file, "input_0");
        assert_eq!(specs[9999].input_file, "input_9999");
        assert_eq!(specs[0].input_bytes, Bytes(2_000_000_000));
        assert_eq!(specs[0].output_bytes, Bytes(4_000));
        assert_eq!(specs[0].id.to_string(), "1.0");
        assert!((specs[0].runtime_median_s - 5.0).abs() < 1e-12);
    }

    #[test]
    fn substitute_macros() {
        assert_eq!(substitute("input_$(Process)", 7, 1), "input_7");
        assert_eq!(substitute("c$(Cluster)_p$(PROCESS)", 2, 9), "c9_p2");
        assert_eq!(substitute("$(Unknown)x", 0, 0), "x");
        assert_eq!(substitute("no_macros", 0, 0), "no_macros");
        assert_eq!(substitute("dangling$(", 0, 0), "dangling$(");
    }

    #[test]
    fn queue_variants() {
        let text = "executable = a.sh\nqueue";
        assert_eq!(parse_submit(text, 1).unwrap().len(), 1);
        let text2 = "executable = a.sh\nqueue 3";
        assert_eq!(parse_submit(text2, 1).unwrap().len(), 3);
    }

    #[test]
    fn missing_required() {
        assert!(matches!(
            parse_submit("queue 1", 1),
            Err(SubmitError::Missing("executable"))
        ));
        assert!(matches!(
            parse_submit("executable = a.sh", 1),
            Err(SubmitError::Missing("queue"))
        ));
    }

    #[test]
    fn rejects_unknown_command() {
        assert!(parse_submit("executable = a\nfrobnicate = 1\nqueue 1", 1).is_err());
    }

    #[test]
    fn accepts_standard_commands_and_custom_attrs() {
        let text = "universe = vanilla\nexecutable = a.sh\nlog = job.log\n\
                    request_memory = 128\n+ProjectName = prp\nqueue 2";
        assert_eq!(parse_submit(text, 4).unwrap().len(), 2);
    }

    #[test]
    fn comments_and_blanks() {
        let text = "# hi\n\nexecutable = a.sh\n  # indented comment\nqueue 1";
        assert_eq!(parse_submit(text, 1).unwrap().len(), 1);
    }
}
