//! `htcdm` CLI — leader entrypoint.
//!
//! ```text
//! htcdm experiment <fig1-lan|fig2-wan|wan-tcp|queue-default|vpn-overlay> [--scale N] [--csv FILE]
//! htcdm pool [--jobs N] [--workers W] [--mb SIZE] [--native]
//! htcdm task [--files N] [--mb SIZE] [--task-dir DIR] [--sim] [--kill-after N]
//! htcdm submit <submit-file>       # parse + print the expanded transaction
//! htcdm verify                     # cross-check PJRT artifact vs native engine
//! htcdm sizing                     # the paper's §II steady-state arithmetic
//! ```

use htcdm::coordinator::{Experiment, Scenario};
use htcdm::fabric::{run_real_pool, RealPoolConfig};
use htcdm::jobs::submit::parse_submit;
use htcdm::mover::AdmissionConfig;
use htcdm::runtime::engine::{Kind, NativeEngine, SealEngine, VerifyingEngine, XlaEngine};
use htcdm::runtime::{Manifest, SealRuntime};
use htcdm::security::Method;
use htcdm::transfer::ThrottlePolicy;
use htcdm::util::Prng;

fn usage() -> ! {
    eprintln!(
        "usage: htcdm <command>\n\
         \n\
         commands:\n\
           experiment <fig1-lan|fig2-wan|wan-tcp|queue-default|vpn-overlay|fair-share|\n\
                       sharded-4|multi-submit-4|hetero-25-100|kill-recover-4|\n\
                       dtn-offload-4|cache-affine-4|petascale-week-3x2>\n\
                      [--scale N] [--csv FILE] [--config FILE]\n\
                      [--solver fair-share|tcp-dynamic]\n\
                      run a paper experiment on the simulated testbed;\n\
                      --solver swaps the netsim flow solver (fair-share is\n\
                      the steady-state max-min default, tcp-dynamic models\n\
                      per-flow slow start / AIMD over the link RTT+loss);\n\
                      --config applies condor-style knobs (JOBS, INPUT_SIZE,\n\
                      N_OWNERS, TRANSFER_QUEUE_POLICY, SHADOW_POOL_SIZE,\n\
                      N_SUBMIT_NODES, ROUTER_POLICY, DATA_NODES,\n\
                      SOURCE_PLAN, DTN_THRESHOLD, SOURCE_SELECTOR,\n\
                      DTN_MAX_CONCURRENT, DTN_QUEUE_DEPTH, N_EXTENTS,\n\
                      ROUTER_SHARDS, CYCLE_SIZE, FAULT_PLAN,\n\
                      STEAL_THRESHOLD, RECOVERY_RAMP, SOLVER,\n\
                      LINK_RTT_MS, LINK_LOSS, N_SITES, SITE_WAN_GBPS,\n\
                      SITE_WAN_RTT_MS, SITE_WAN_LOSS, SITE_SELECTOR...;\n\
                      docs/KNOBS.md is the full reference)\n\
           pool       [--jobs N] [--workers W] [--mb SIZE] [--native]\n\
                      [--shadows N] [--policy disabled|disk-load|max-concurrent|fair-share|weighted-by-size]\n\
                      [--cap N] [--submit-nodes N] [--node-gbps G1,G2,...]\n\
                      [--router round-robin|least-loaded|owner-affinity|weighted-by-capacity]\n\
                      [--data-nodes N] [--source funnel|dtn|hybrid[:BYTES]]\n\
                      [--source-selector round-robin|cache-aware|owner-affinity|weighted-by-capacity]\n\
                      [--dtn-cap N] [--dtn-queue N] [--router-shards K]\n\
                      [--cycle N] [--fault PLAN] [--steal N] [--ramp N]\n\
                      [--sites N] [--site-selector local-first|cache-aware|round-robin]\n\
                      run a real-mode loopback pool (sealed bytes via PJRT);\n\
                      --submit-nodes > 1 runs one file server per submit node\n\
                      behind the pool router; --data-nodes N serves bytes\n\
                      from N dedicated DTN file servers under --source,\n\
                      placed by --source-selector with --dtn-cap slots\n\
                      of admission budget per data node (0 = unlimited)\n\
                      and --dtn-queue N wait-queue entries behind them;\n\
                      --router-shards K shards the router's ticket maps\n\
                      (identical decisions, less lock contention) and\n\
                      --cycle N batches admission in N-request cycles;\n\
                      --fault injects chaos, e.g. 'kill:1@0.5; recover:1@2;\n\
                      kill:d0@1; kill:s0@2' (wall-clock seconds, dN = data\n\
                      node, sN = whole site), with --steal N enabling\n\
                      work-stealing past an N-deep queue imbalance and\n\
                      --ramp N hysteretic recovery; --sites N federates\n\
                      the submit/DTN fleets into N sites and\n\
                      --site-selector picks the source site before the\n\
                      in-site selector runs\n\
           task       [--files N] [--mb SIZE] [--name NAME] [--owner NAME]\n\
                      [--task-dir DIR] [--rate-mbps R] [--deadline-s S]\n\
                      [--autotune] [--concurrency N] [--workers W] [--sim]\n\
                      [--kill-after N] [--data-nodes N]\n\
                      [--source funnel|dtn|hybrid[:BYTES]] [--native]\n\
                      run a durable multi-file transfer task: per-file\n\
                      checkpoints journalled under --task-dir survive a\n\
                      coordinator restart (re-run the same command to\n\
                      resume; completed files are never re-transferred,\n\
                      every file is SHA-256-verified); --rate-mbps and\n\
                      --deadline-s bound admission, --autotune closes the\n\
                      concurrency/chunk loop on observed goodput,\n\
                      --kill-after N simulates a coordinator crash after\n\
                      N files, --sim drives the virtual-time engine\n\
                      instead of the loopback fabric\n\
           submit     <file>   parse a submit description and print the jobs\n\
           verify              cross-check the PJRT artifact vs the native engine\n\
           sizing              print the paper's steady-state pool arithmetic"
    );
    std::process::exit(2)
}

fn arg_value(args: &[String], flag: &str) -> Option<String> {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1).cloned())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("experiment") => cmd_experiment(&args[1..]),
        Some("pool") => cmd_pool(&args[1..]),
        Some("task") => cmd_task(&args[1..]),
        Some("submit") => cmd_submit(&args[1..]),
        Some("verify") => cmd_verify(),
        Some("sizing") => {
            println!(
                "§II sizing: 20k slots × (3 min transfer / 6 h job) = {:.1} slots in transfer \
                 (paper rounds to ~200)",
                htcdm::workload::paper_sizing()
            );
            Ok(())
        }
        _ => usage(),
    }
}

fn cmd_experiment(args: &[String]) -> anyhow::Result<()> {
    let scenario = match args.first().map(|s| s.as_str()) {
        Some("fig1-lan") => Scenario::LanPaper,
        Some("fig2-wan") => Scenario::WanPaper,
        Some("wan-tcp") => Scenario::WanTcpDynamic,
        Some("queue-default") => Scenario::LanDefaultQueue,
        Some("vpn-overlay") => Scenario::LanVpn,
        Some("fair-share") => Scenario::LanFairShare,
        Some("sharded-4") => Scenario::LanSharded4,
        Some("multi-submit-4") => Scenario::LanMultiSubmit4,
        Some("hetero-25-100") => Scenario::Hetero25100,
        Some("kill-recover-4") => Scenario::KillRecover4,
        Some("dtn-offload-4") => Scenario::DtnOffload4,
        Some("cache-affine-4") => Scenario::CacheAffine4,
        Some("petascale-week-3x2") => Scenario::PetascaleWeek3x2,
        _ => usage(),
    };
    let scale: u32 = arg_value(args, "--scale")
        .map(|v| v.parse().expect("--scale N"))
        .unwrap_or(1);
    let mut exp = Experiment::scenario(scenario).scaled(scale);
    if let Some(path) = arg_value(args, "--config") {
        let cfg = htcdm::config::Config::parse(&std::fs::read_to_string(&path)?)?;
        exp.spec.apply_config(&cfg)?;
        eprintln!("applied config {path}");
    }
    if let Some(name) = arg_value(args, "--solver") {
        exp.spec.solver = htcdm::netsim::solver::SolverKind::parse(&name).unwrap_or_else(|| {
            eprintln!("unknown --solver '{name}'");
            usage()
        });
    }
    eprintln!(
        "running {} ({} jobs, {} solver)...",
        exp.label,
        exp.spec.n_jobs,
        exp.spec.solver.label()
    );
    let report = exp.run()?;
    println!(
        "{}",
        report.table_row(
            scenario.paper_sustained_gbps(),
            scenario.paper_makespan_min()
        )
    );
    println!("\nSubmit-NIC throughput (5-min bins, as in the paper's Fig.):");
    println!("{}", report.figure(100.0));
    if report.n_submit_nodes > 1 {
        println!(
            "router: {} over {} submit nodes | per-node jobs {:?} | per-node GB {:?}",
            report.router_policy,
            report.n_submit_nodes,
            report.router.routed_per_node,
            report
                .router
                .bytes_per_node
                .iter()
                .map(|b| (*b as f64 / 1e9 * 10.0).round() / 10.0)
                .collect::<Vec<_>>()
        );
    }
    if report.n_data_nodes > 0 {
        println!(
            "sources: {} over {} data nodes by {} | per-dtn jobs {:?} | per-dtn GB {:?} | \
             submit-NIC GB {:?}",
            report.source_plan,
            report.n_data_nodes,
            report.source_selector,
            report.router.routed_per_dtn,
            report
                .router
                .bytes_per_dtn
                .iter()
                .map(|b| (*b as f64 / 1e9 * 10.0).round() / 10.0)
                .collect::<Vec<_>>(),
            report
                .per_node_series
                .iter()
                .map(|s| (s.total_bytes() / 1e9 * 10.0).round() / 10.0)
                .collect::<Vec<_>>()
        );
    }
    if report.n_sites > 1 {
        println!(
            "federation: {} site(s) by {} | cross-site GB {:.1} | site×site GB {:?}",
            report.n_sites,
            report.site_selector,
            report.cross_site_bytes() as f64 / 1e9,
            report
                .site_matrix_bytes
                .iter()
                .map(|row| {
                    row.iter()
                        .map(|b| (*b as f64 / 1e9 * 10.0).round() / 10.0)
                        .collect::<Vec<_>>()
                })
                .collect::<Vec<_>>()
        );
    }
    if !report.chaos.is_empty() {
        println!("\nfault timeline:\n{}", report.chaos.render());
        println!(
            "chaos: nodes failed {} / recovered {} | transfers retried-after-fault {} | \
             work-stolen {}",
            report.mover.shard_failed,
            report.mover.node_recovered,
            report.mover.retried_after_fault,
            report.mover.stolen
        );
    }
    if let Some(csv) = arg_value(args, "--csv") {
        std::fs::write(&csv, htcdm::metrics::to_csv(&report.series))?;
        eprintln!("wrote {csv}");
    }
    Ok(())
}

fn cmd_pool(args: &[String]) -> anyhow::Result<()> {
    use htcdm::mover::RouterPolicy;
    let cap: u32 = arg_value(args, "--cap")
        .map(|v| v.parse().expect("--cap N"))
        .unwrap_or(0);
    let router = match arg_value(args, "--router") {
        None => RouterPolicy::LeastLoaded,
        Some(name) => RouterPolicy::parse(&name).unwrap_or_else(|| {
            eprintln!("unknown --router '{name}'");
            usage()
        }),
    };
    let limit = if cap == 0 { u32::MAX } else { cap };
    let policy = match arg_value(args, "--policy").as_deref() {
        None | Some("disabled") => AdmissionConfig::Throttle(ThrottlePolicy::Disabled),
        Some("disk-load") => ThrottlePolicy::htcondor_default().into(),
        Some("max-concurrent") => ThrottlePolicy::MaxConcurrent(limit).into(),
        Some("fair-share") => AdmissionConfig::FairShare { limit },
        Some("weighted-by-size") => AdmissionConfig::WeightedBySize { limit },
        Some(other) => {
            eprintln!("unknown --policy '{other}'");
            usage()
        }
    };
    let mut faults = match arg_value(args, "--fault") {
        None => htcdm::mover::FaultPlan::default(),
        Some(text) => htcdm::mover::FaultPlan::parse(&text).unwrap_or_else(|e| {
            eprintln!("bad --fault plan: {e}");
            usage()
        }),
    };
    if let Some(th) = arg_value(args, "--steal") {
        faults.steal_threshold = Some(th.parse().expect("--steal N"));
    }
    if let Some(r) = arg_value(args, "--ramp") {
        faults.recovery_ramp = Some(r.parse().expect("--ramp N"));
    }
    let source = match arg_value(args, "--source") {
        None => htcdm::mover::SourcePlan::SubmitFunnel,
        Some(name) => htcdm::mover::SourcePlan::parse(&name).unwrap_or_else(|| {
            eprintln!("unknown --source '{name}'");
            usage()
        }),
    };
    let source_selector = match arg_value(args, "--source-selector") {
        None => htcdm::mover::SourceSelector::RoundRobin,
        Some(name) => htcdm::mover::SourceSelector::parse(&name).unwrap_or_else(|| {
            eprintln!("unknown --source-selector '{name}'");
            usage()
        }),
    };
    let site_selector = match arg_value(args, "--site-selector") {
        None => htcdm::mover::SiteSelector::LocalFirst,
        Some(name) => htcdm::mover::SiteSelector::parse(&name).unwrap_or_else(|| {
            eprintln!("unknown --site-selector '{name}'");
            usage()
        }),
    };
    let cfg = RealPoolConfig {
        n_jobs: arg_value(args, "--jobs").map(|v| v.parse().unwrap()).unwrap_or(40),
        workers: arg_value(args, "--workers").map(|v| v.parse().unwrap()).unwrap_or(4),
        input_bytes: arg_value(args, "--mb")
            .map(|v| v.parse::<usize>().unwrap() << 20)
            .unwrap_or(4 << 20),
        use_xla_engine: !args.iter().any(|a| a == "--native"),
        shadows: arg_value(args, "--shadows")
            .map(|v| v.parse().expect("--shadows N"))
            .unwrap_or(1),
        policy,
        n_submit_nodes: arg_value(args, "--submit-nodes")
            .map(|v| v.parse().expect("--submit-nodes N"))
            .unwrap_or(1),
        router,
        node_capacities: arg_value(args, "--node-gbps")
            .map(|v| {
                v.split(',')
                    .map(|s| s.trim().parse::<f64>().expect("--node-gbps G1,G2,..."))
                    .collect()
            })
            .unwrap_or_default(),
        data_nodes: arg_value(args, "--data-nodes")
            .map(|v| v.parse().expect("--data-nodes N"))
            .unwrap_or(0),
        source,
        source_selector,
        dtn_slots: arg_value(args, "--dtn-cap")
            .map(|v| v.parse().expect("--dtn-cap N"))
            .unwrap_or(0),
        dtn_queue_depth: arg_value(args, "--dtn-queue")
            .map(|v| v.parse().expect("--dtn-queue N"))
            .unwrap_or(0),
        router_shards: arg_value(args, "--router-shards")
            .map(|v| v.parse().expect("--router-shards K"))
            .unwrap_or(htcdm::mover::DEFAULT_ROUTER_SHARDS),
        cycle_size: arg_value(args, "--cycle")
            .map(|v| v.parse().expect("--cycle N"))
            .unwrap_or(0),
        faults,
        n_sites: arg_value(args, "--sites")
            .map(|v| v.parse().expect("--sites N"))
            .unwrap_or(1),
        site_selector,
        ..Default::default()
    };
    eprintln!(
        "real-mode pool: {} jobs × {} MiB over {} workers, {} submit node(s) ({} router), \
         {} data node(s) ({} sources), {} shadow shard(s)/node, policy {}...",
        cfg.n_jobs,
        cfg.input_bytes >> 20,
        cfg.workers,
        cfg.n_submit_nodes,
        cfg.router.label(),
        cfg.data_nodes,
        cfg.source.label(),
        cfg.shadows,
        cfg.policy.label()
    );
    let r = run_real_pool(cfg)?;
    println!(
        "engine {} | solver {} | {} jobs | {:.1} MiB moved | {:.2} s wall | {:.3} Gbps | median transfer {:.3} s | errors {}",
        r.engine_desc,
        r.solver,
        r.jobs_completed,
        r.total_payload_bytes as f64 / (1 << 20) as f64,
        r.wall_secs,
        r.gbps,
        r.transfer_secs.median(),
        r.errors
    );
    println!(
        "mover: peak active {} | per-shard jobs {:?} | spurious completes {}",
        r.mover.peak_active, r.mover.admitted_per_shard, r.mover.released_without_active
    );
    if r.router.routed_per_node.len() > 1 {
        println!(
            "router: per-node jobs {:?} | per-node MiB served {:?} | failed nodes {}",
            r.router.routed_per_node,
            r.bytes_served_per_node
                .iter()
                .map(|b| b >> 20)
                .collect::<Vec<_>>(),
            r.router.shard_failed
        );
    }
    if !r.bytes_served_per_dtn.is_empty() {
        println!(
            "sources: {} by {} | per-dtn jobs {:?} | per-dtn MiB served {:?} | submit MiB served {:?} \
             | failed dtns {}",
            r.source_plan,
            r.source_selector,
            r.router.routed_per_dtn,
            r.bytes_served_per_dtn
                .iter()
                .map(|b| b >> 20)
                .collect::<Vec<_>>(),
            r.bytes_served_per_node
                .iter()
                .map(|b| b >> 20)
                .collect::<Vec<_>>(),
            r.router.dtn_failed
        );
    }
    if r.n_sites > 1 {
        println!(
            "federation: {} site(s) | site×site MiB {:?}",
            r.n_sites,
            r.site_matrix_bytes
                .iter()
                .map(|row| row.iter().map(|b| b >> 20).collect::<Vec<_>>())
                .collect::<Vec<_>>()
        );
    }
    if !r.chaos.is_empty() {
        println!("fault timeline:\n{}", r.chaos.render());
        println!(
            "chaos: recovered {} | retried-after-fault {} | work-stolen {}",
            r.mover.node_recovered, r.mover.retried_after_fault, r.mover.stolen
        );
    }
    Ok(())
}

fn cmd_task(args: &[String]) -> anyhow::Result<()> {
    use htcdm::fabric::{run_real_task, RealTaskConfig};
    use htcdm::mover::{tuner_json, TaskJournal, TaskRunner, TransferTask};

    let n_files: usize = arg_value(args, "--files")
        .map(|v| v.parse().expect("--files N"))
        .unwrap_or(8);
    let mb: u64 = arg_value(args, "--mb")
        .map(|v| v.parse().expect("--mb SIZE"))
        .unwrap_or(4);
    let name = arg_value(args, "--name").unwrap_or_else(|| "task".into());
    let owner = arg_value(args, "--owner").unwrap_or_else(|| "cli".into());
    let mut task = TransferTask::new(name.as_str(), owner.as_str()).with_uniform_files(
        "input",
        n_files,
        mb << 20,
    );
    if let Some(r) = arg_value(args, "--rate-mbps") {
        let mbps: f64 = r.parse().expect("--rate-mbps R");
        task = task.with_rate_bps((mbps * 1e6) as u64);
    }
    if let Some(d) = arg_value(args, "--deadline-s") {
        task = task.with_deadline_s(d.parse().expect("--deadline-s S"));
    }
    if args.iter().any(|a| a == "--autotune") {
        task = task.with_autotune(true);
    }
    if let Some(c) = arg_value(args, "--concurrency") {
        task = task.with_concurrency(c.parse().expect("--concurrency N"));
    }
    let journal = match arg_value(args, "--task-dir") {
        Some(dir) => TaskJournal::dir(std::path::PathBuf::from(dir))?,
        None => TaskJournal::memory(),
    };
    let runner = TaskRunner::new(task, journal)?;
    if runner.files_resumed() > 0 {
        eprintln!(
            "resuming '{name}': {} of {n_files} files already checkpointed done",
            runner.files_resumed()
        );
    }
    let kill_after: Option<usize> =
        arg_value(args, "--kill-after").map(|v| v.parse().expect("--kill-after N"));

    if args.iter().any(|a| a == "--sim") {
        use htcdm::coordinator::engine::{run_task_sim_with_kill, EngineSpec};
        use htcdm::netsim::topology::TestbedSpec;
        let spec = EngineSpec::paper(TestbedSpec::lan_paper(), ThrottlePolicy::Disabled);
        let mut runner = runner;
        let r = run_task_sim_with_kill(&spec, &mut runner, kill_after)?;
        println!(
            "sim task '{}': {}/{} files done ({} resumed) | {:.1} MiB verified | {:.2} s \
             makespan | retries {} | killed {}",
            name,
            r.progress.files_done,
            r.progress.files_total,
            r.progress.files_resumed,
            r.progress.verified_bytes as f64 / (1 << 20) as f64,
            r.makespan_s,
            r.progress.retries,
            r.killed,
        );
        println!("{}", r.progress.to_json());
        if !r.tuner.is_empty() {
            println!("tuner trajectory: {}", tuner_json(&r.tuner));
        }
    } else {
        let source = match arg_value(args, "--source") {
            None => htcdm::mover::SourcePlan::SubmitFunnel,
            Some(s) => htcdm::mover::SourcePlan::parse(&s).unwrap_or_else(|| {
                eprintln!("unknown --source '{s}'");
                usage()
            }),
        };
        let cfg = RealTaskConfig {
            workers: arg_value(args, "--workers")
                .map(|v| v.parse().expect("--workers W"))
                .unwrap_or(4),
            use_xla_engine: !args.iter().any(|a| a == "--native"),
            data_nodes: arg_value(args, "--data-nodes")
                .map(|v| v.parse().expect("--data-nodes N"))
                .unwrap_or(0),
            source,
            kill_after_files: kill_after,
            ..Default::default()
        };
        let (r, _runner) = run_real_task(&cfg, runner)?;
        println!(
            "real task '{}': {}/{} files done ({} resumed) | {:.1} MiB moved | {:.2} s wall | \
             errors {} | killed {}",
            name,
            r.progress.files_done,
            r.progress.files_total,
            r.progress.files_resumed,
            r.payload_bytes as f64 / (1 << 20) as f64,
            r.wall_secs,
            r.errors,
            r.killed,
        );
        println!("{}", r.progress.to_json());
        if !r.tuner.is_empty() {
            println!("tuner trajectory: {}", tuner_json(&r.tuner));
        }
    }
    Ok(())
}

fn cmd_submit(args: &[String]) -> anyhow::Result<()> {
    let path = args.first().cloned().unwrap_or_else(|| usage());
    let text = std::fs::read_to_string(&path)?;
    let specs = parse_submit(&text, 1)?;
    println!("transaction: {} jobs", specs.len());
    for s in specs.iter().take(5) {
        println!("  {} input={} ({})", s.id, s.input_file, s.input_bytes);
    }
    if specs.len() > 5 {
        println!("  ... and {} more", specs.len() - 5);
    }
    Ok(())
}

fn cmd_verify() -> anyhow::Result<()> {
    let manifest = Manifest::load(Manifest::default_dir())?;
    let rt = SealRuntime::load(&manifest, &["probe", "64k"])?;
    let mut v = VerifyingEngine::new(XlaEngine::new(rt), NativeEngine::new(Method::Chacha20));
    let mut rng = Prng::new(0xC0FFEE);
    for round in 0..4u32 {
        let mut key = [0u32; 8];
        let mut nonce = [0u32; 3];
        key.iter_mut().for_each(|k| *k = rng.next_u32());
        nonce.iter_mut().for_each(|n| *n = rng.next_u32());
        let mut data: Vec<u32> = (0..1024 * 16).map(|_| rng.next_u32()).collect();
        v.process(Kind::Seal, &key, &nonce, round * 1024, &mut data)?;
        v.process(Kind::Unseal, &key, &nonce, round * 1024, &mut data)?;
    }
    println!(
        "OK: {} chunks bit-identical between PJRT artifact and native engine",
        v.chunks_verified
    );
    Ok(())
}
