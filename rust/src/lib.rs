//! # htcdm — HTCondor-style data movement at 100 Gbps
//!
//! A from-scratch reproduction of the system benchmarked in
//! *"HTCondor data movement at 100 Gbps"* (Sfiligoi, Würthwein, DeFanti,
//! Graham — eScience 2021): a distributed high-throughput workload manager
//! whose native file-transfer architecture routes every job's sandbox
//! through the submit node, with end-to-end authentication, encryption and
//! integrity checking.
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the coordinator: ClassAd matchmaking, a schedd
//!   with a job queue and transfer queue, startds with execute slots,
//!   shadow/starter transfer endpoints, and two interchangeable fabrics:
//!   a fluid-flow network *simulator* calibrated to the paper's testbed
//!   (100 Gbps NICs, cross-US WAN, Calico VPN overlay) and a *real* TCP
//!   fabric that moves actual sealed bytes.
//! * **L2 (python/compile/model.py)** — the sealed-transfer pipeline as a
//!   JAX computation, AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels/chacha.py)** — the Pallas kernel: fused
//!   ChaCha20 + poly16 integrity digest.
//!
//! The [`runtime`] module loads the AOT artifacts via the PJRT C API and
//! executes them from the transfer hot path — Python is never on the
//! request path.
//!
//! Prose companions to this rustdoc live in `docs/`:
//! `docs/ARCHITECTURE.md` (the layered tour with diagrams),
//! `docs/KNOBS.md` (every config knob, CLI flag and environment
//! variable) and `docs/REPORTS.md` (the schemas of everything a run
//! emits). CI link-checks them alongside `cargo doc`.
//!
//! ## Data mover architecture
//!
//! Sandbox data movement is owned end-to-end by the [`mover`] subsystem,
//! and the two fabrics consume it identically:
//!
//! ```text
//!              requests (ticket, owner, bytes)
//!                      │
//!              ┌───────▼────────┐   RouterPolicy (pluggable):
//!              │   PoolRouter   │   round-robin · least-loaded ·
//!              │ node 0..M-1    │   owner-affinity · weighted-by-
//!              └───────┬────────┘   NIC-capacity (+ fail_node drain)
//!                      │ routed to one submit node
//!              ┌───────▼────────┐   AdmissionPolicy (pluggable, per
//!              │ AdmissionQueue │   node): fifo/disabled · fifo/disk-
//!              │ (policy-driven)│   load · fifo/max-concurrent ·
//!              └───────┬────────┘   fair-share · weighted-by-size
//!                      │ admitted
//!              ┌───────▼────────┐
//!              │   ShadowPool   │   least-loaded shard assignment
//!              │  shard 0..N-1  │   (one SealEngine service per shard
//!              └───┬────────┬───┘    in real mode)
//!        sim mode  │        │  real mode
//!   fluid flows over M      │  sealed frames over TCP: one FileServer
//!   monitored submit NICs   │  per submit node, each connection sealed
//!   (coordinator::engine)   │  by its shard's engine (fabric::tcp)
//! ```
//!
//! ## Data source plane
//!
//! The paper's central caveat — both input and output data route
//! through the submission node — is now one *configuration* of a
//! pluggable endpoint layer ([`mover::source`]). A [`mover::SourcePlan`]
//! decides, per admitted transfer, which endpoint serves its bytes;
//! every routing decision is a `(schedule node, data source)` pair:
//!
//! ```text
//!         submit-funnel (paper baseline)        dedicated-dtn / hybrid
//!
//!         ┌────────────┐                        ┌────────────┐ scheduling
//!         │ submit node│ scheduling             │ submit node│ control
//!         │  + bytes   │ + every byte           └────────────┘ only
//!         └─────┬──────┘                        ┌────┐ ┌────┐ ┌────┐
//!               │ NIC (the ~90 Gbps             │dtn0│ │dtn1│ │dtn2│ bytes
//!               ▼      ceiling)                 └──┬─┘ └──┬─┘ └──┬─┘
//!         ┌──────────┐                             ▼      ▼      ▼
//!         │ workers  │                          ┌──────────────────┐
//!         └──────────┘                          │     workers      │
//!                                               └──────────────────┘
//! ```
//!
//! * `SubmitFunnel` — today's behavior; `DedicatedDtn` — a DTN fleet
//!   with its own monitored NICs (outside the VPN overlay) serves every
//!   byte while the submit node keeps only scheduling; `Hybrid` — small
//!   sandboxes ride the funnel, sandboxes at/above `DTN_THRESHOLD` go
//!   via DTNs. Knobs: `DATA_NODES` / `SOURCE_PLAN` / `DTN_THRESHOLD` /
//!   `DATA_NODE_GBPS` in [`config`], `--data-nodes` / `--source` on the
//!   CLI, and the `dtn-offload-4` scenario (4 × 100 Gbps DTNs behind
//!   one scheduling node).
//! * *Which* live data node serves a fleet-bound transfer is the
//!   [`mover::SourceSelector`]'s call (`SOURCE_SELECTOR` /
//!   `--source-selector`): the deterministic round-robin rotation,
//!   **cache-aware** placement steering a transfer to the DTN already
//!   holding its [`storage::ExtentId`] hot (per-DTN residency tracked
//!   by the router and, in the sim, backed by a real per-node
//!   [`storage::Storage`] cache model — warm extents stream at
//!   page-cache rate, cold ones at the device's), **owner-affinity**
//!   pinning each owner's sandboxes to a stable DTN with failure-aware
//!   re-pinning, or **weighted-by-capacity** deficit selection matching
//!   heterogeneous `DATA_NODE_GBPS` fleets. Every DTN also carries its
//!   own admission budget (`DTN_MAX_CONCURRENT` / `--dtn-cap`): a
//!   saturated node pushes back (`MoverStats::dtn_deferred`), and a
//!   fully saturated fleet overflows to the funnel
//!   (`MoverStats::dtn_overflow_to_funnel`). The `cache-affine-4`
//!   scenario proves the steering pays: on a warm-extent burst the
//!   cache-aware selector beats blind round-robin on both makespan and
//!   goodput.
//! * Selection is failure-aware: a killed DTN's in-flight transfers
//!   re-source onto survivors or fall back to the funnel
//!   ([`mover::PoolRouter::fail_dtn`]), without touching their
//!   admission slots — and the dead node's residency and owner pins die
//!   with it. Chaos plans address data nodes with the `dN` spelling
//!   (`kill:d0@30`), and `flap:N@T:PERIOD:GBPS` expands into periodic
//!   slow-NIC degrade/restore cycles.
//! * Reports carry one NIC series per source (`Report::per_node_series`
//!   + `Report::per_dtn_series`, summing element-wise to
//!   `Report::series`), so the acceptance experiment is a one-liner:
//!   under `dtn-offload-4` the submit NIC series stays near-idle while
//!   aggregate goodput matches the funnel baseline.
//!
//! * The schedd ([`daemons::schedd`]) delegates all routing and
//!   admission mechanics to its [`mover::PoolRouter`] — a single-node
//!   router is exactly the paper's one submit node.
//! * [`mover::RouterPolicy`] is the scale-out knob the paper motivates
//!   (its ~90 Gbps plateau is one submit NIC): `N_SUBMIT_NODES` /
//!   `ROUTER_POLICY` in [`config`], `--submit-nodes` / `--router` on the
//!   CLI. [`mover::PoolRouter::fail_node`] re-routes a dead node's
//!   waiting *and* in-flight transfers to the survivors (counted in
//!   `MoverStats::shard_failed`; re-routed in-flight transfers in
//!   `MoverStats::retried_after_fault`), so bursts drain through
//!   failures.
//! * The [`mover::chaos`] layer makes failures a first-class scenario
//!   knob: a [`mover::FaultPlan`] — ordered `kill:N@T` / `recover:N@T` /
//!   `degrade:N@T:GBPS` events (`FAULT_PLAN` / `STEAL_THRESHOLD` in
//!   [`config`], `--fault` / `--steal` on the CLI, `kill-recover-4`
//!   scenario) — is executed identically by both fabrics. The simulator
//!   aborts the dead node's in-flight flows and re-rates its monitored
//!   NIC; the real fabric crashes the node's `FileServer` mid-connection
//!   and restarts it on recovery, with workers retrying through the
//!   router. Recovery un-poisons the node
//!   ([`mover::PoolRouter::recover_node`], `MoverStats::node_recovered`)
//!   and [`mover::PoolRouter::rebalance`] work-steals waiting transfers
//!   from long survivor queues onto it until the max/min queue gap is
//!   within the configured threshold (`MoverStats::stolen`). With
//!   `RECOVERY_RAMP` / `--ramp` set, recovery is hysteretic: the node's
//!   weighted-by-capacity routing weight ramps back over that many
//!   decisions instead of step-restoring
//!   ([`mover::RouterConfig::recovery_ramp`]). Reports carry the
//!   per-node fault timeline (`Report::chaos`,
//!   `RealPoolReport::chaos`).
//! * [`mover::AdmissionPolicy`] generalizes HTCondor's
//!   `FILE_TRANSFER_DISK_LOAD_THROTTLE`: the three classic throttles stay
//!   FIFO, while `FairShare` adds starvation-free per-owner round-robin
//!   and `WeightedBySize` admits the smallest sandbox first.
//! * Shadow count and policy are scenario knobs
//!   ([`coordinator::experiment`], `TRANSFER_QUEUE_POLICY` /
//!   `SHADOW_POOL_SIZE` in [`config`]), so the paper's single-funnel
//!   submit node, multi-shard and multi-submit-node scaling variants run
//!   from the same code.
//! * Reports carry one NIC series per submit node
//!   (`Report::per_node_series`); the aggregate `Report::series` is
//!   their element-wise sum ([`metrics::BinSeries::sum`]).
//! * `tests/mover_unified.rs` drives one `ShadowPool` object through the
//!   simulator and then the real TCP fabric; `tests/router_unified.rs`
//!   does the same with one multi-node `PoolRouter`; and
//!   `tests/chaos_unified.rs` drives one `FaultPlan` shape through both
//!   fabrics, proving the whole path — router and chaos layer included —
//!   is shared.
//!
//! ## Quickstart
//!
//! ```no_run
//! use htcdm::coordinator::experiment::{Experiment, Scenario};
//!
//! // Reproduce the paper's Fig. 1 (LAN, 10k jobs, 200 slots):
//! let report = Experiment::scenario(Scenario::LanPaper).run().unwrap();
//! println!("sustained {:.1} Gbps", report.sustained_gbps());
//! ```

pub mod classad;
pub mod config;
pub mod coordinator;
pub mod daemons;
pub mod fabric;
pub mod jobs;
pub mod metrics;
pub mod mover;
pub mod netsim;
pub mod runtime;
pub mod security;
pub mod sim;
pub mod storage;
pub mod transfer;
pub mod util;
pub mod workload;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
