//! # htcdm — HTCondor-style data movement at 100 Gbps
//!
//! A from-scratch reproduction of the system benchmarked in
//! *"HTCondor data movement at 100 Gbps"* (Sfiligoi, Würthwein, DeFanti,
//! Graham — eScience 2021): a distributed high-throughput workload manager
//! whose native file-transfer architecture routes every job's sandbox
//! through the submit node, with end-to-end authentication, encryption and
//! integrity checking.
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the coordinator: ClassAd matchmaking, a schedd
//!   with a job queue and transfer queue, startds with execute slots,
//!   shadow/starter transfer endpoints, and two interchangeable fabrics:
//!   a fluid-flow network *simulator* calibrated to the paper's testbed
//!   (100 Gbps NICs, cross-US WAN, Calico VPN overlay) and a *real* TCP
//!   fabric that moves actual sealed bytes.
//! * **L2 (python/compile/model.py)** — the sealed-transfer pipeline as a
//!   JAX computation, AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels/chacha.py)** — the Pallas kernel: fused
//!   ChaCha20 + poly16 integrity digest.
//!
//! The [`runtime`] module loads the AOT artifacts via the PJRT C API and
//! executes them from the transfer hot path — Python is never on the
//! request path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use htcdm::coordinator::experiment::{Experiment, Scenario};
//!
//! // Reproduce the paper's Fig. 1 (LAN, 10k jobs, 200 slots):
//! let report = Experiment::scenario(Scenario::LanPaper).run().unwrap();
//! println!("sustained {:.1} Gbps", report.sustained_gbps());
//! ```

pub mod classad;
pub mod config;
pub mod coordinator;
pub mod daemons;
pub mod fabric;
pub mod jobs;
pub mod metrics;
pub mod netsim;
pub mod runtime;
pub mod security;
pub mod sim;
pub mod storage;
pub mod transfer;
pub mod util;
pub mod workload;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
