//! Per-flow TCP throughput model.
//!
//! A fluid flow's *cap* is the steady-state throughput a single TCP stream
//! can reach on its path, independent of fair-share contention:
//!
//!   cap = min( window / RTT,                 — receive/congestion window
//!              Mathis MSS/(RTT·√p) · C,      — loss-limited (WAN)
//!              per-stream endpoint ceiling ) — one shadow/starter pair's
//!                                              crypto+syscall throughput
//!
//! On the LAN (RTT ≈ 0.2 ms, p ≈ 0) the endpoint ceiling dominates; across
//! the US (RTT 58 ms over CENIC/I2/NYSERNet) the loss term does — which is
//! exactly the mechanism the paper suspects for its 90 → 60 Gbps drop.
//!
//! Flow *setup* latency models the HTCondor shadow→starter handshake
//! (TCP + authentication + key exchange ≈ `HANDSHAKE_RTTS` round trips)
//! plus a slow-start ramp allowance.

use super::calib;

/// Path characteristics seen by one transfer stream.
#[derive(Debug, Clone, Copy)]
pub struct PathProfile {
    /// Round-trip time in seconds.
    pub rtt_s: f64,
    /// Packet loss probability on the path (fraction, e.g. 6e-7).
    pub loss: f64,
    /// Kernel TCP window limit in bytes (rmem/wmem autotuning cap).
    pub window_bytes: f64,
    /// Per-stream endpoint ceiling in bytes/sec (crypto + syscall path of
    /// one shadow/starter pair).
    pub endpoint_bps: f64,
}

impl PathProfile {
    pub fn lan() -> PathProfile {
        PathProfile {
            rtt_s: calib::LAN_RTT_S,
            loss: calib::LAN_LOSS,
            window_bytes: calib::TCP_WINDOW_BYTES,
            endpoint_bps: calib::PER_STREAM_ENDPOINT_BPS,
        }
    }

    pub fn wan() -> PathProfile {
        PathProfile {
            rtt_s: calib::WAN_RTT_S,
            loss: calib::WAN_LOSS,
            window_bytes: calib::TCP_WINDOW_BYTES,
            endpoint_bps: calib::PER_STREAM_ENDPOINT_BPS,
        }
    }

    /// Steady-state throughput cap of one stream (bytes/sec).
    pub fn stream_cap_bps(&self) -> f64 {
        let window_limit = self.window_bytes / self.rtt_s;
        let loss_limit = if self.loss > 0.0 {
            // Mathis et al.: rate = (MSS/RTT) · C/√p, C ≈ 1.22 (delayed acks off).
            (calib::MSS_BYTES / self.rtt_s) * (calib::MATHIS_C / self.loss.sqrt())
        } else {
            f64::INFINITY
        };
        window_limit.min(loss_limit).min(self.endpoint_bps)
    }

    /// Steady-state cap of one stream with the loss term excluded
    /// (bytes/sec): window and endpoint ceilings only. This is the cap a
    /// *dynamic* solver should see — it models loss and the ramp in-band
    /// via the congestion window, so folding the Mathis limit in here
    /// would count loss twice.
    pub fn stream_cap_loss_free_bps(&self) -> f64 {
        (self.window_bytes / self.rtt_s).min(self.endpoint_bps)
    }

    /// Connection + auth handshake latency before bytes flow (seconds).
    pub fn setup_latency_s(&self) -> f64 {
        // Handshake round trips + slow-start ramp to reach the cap:
        // doubling from IW≈10 MSS each RTT until cwnd ≈ cap·RTT.
        let cap = self.stream_cap_bps();
        let target_w = (cap * self.rtt_s).max(calib::MSS_BYTES * 10.0);
        let ramp_rtts = (target_w / (calib::MSS_BYTES * 10.0)).log2().max(0.0);
        (calib::HANDSHAKE_RTTS + ramp_rtts) * self.rtt_s
    }

    /// Handshake-only setup latency (seconds) — the companion of
    /// [`PathProfile::stream_cap_loss_free_bps`] for dynamic solvers,
    /// which replay the slow-start ramp themselves.
    pub fn handshake_latency_s(&self) -> f64 {
        calib::HANDSHAKE_RTTS * self.rtt_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::Gbps;

    #[test]
    fn lan_cap_is_endpoint_bound() {
        let p = PathProfile::lan();
        let cap = p.stream_cap_bps();
        assert!(
            (cap - calib::PER_STREAM_ENDPOINT_BPS).abs() < 1.0,
            "LAN streams are limited by the endpoint crypto path, got {cap}"
        );
    }

    #[test]
    fn wan_cap_is_loss_bound_near_300_mbps() {
        let p = PathProfile::wan();
        let cap_gbps = Gbps::from_bytes_per_sec(p.stream_cap_bps()).0;
        // Calibration target: ~200 streams aggregate to ≈60 Gbps.
        assert!(
            (0.25..0.40).contains(&cap_gbps),
            "WAN per-stream cap should be ≈0.3 Gbps, got {cap_gbps}"
        );
    }

    #[test]
    fn wan_slower_than_lan_per_stream() {
        assert!(PathProfile::wan().stream_cap_bps() < PathProfile::lan().stream_cap_bps());
    }

    #[test]
    fn setup_latency_scales_with_rtt() {
        let lan = PathProfile::lan().setup_latency_s();
        let wan = PathProfile::wan().setup_latency_s();
        assert!(wan > lan * 50.0, "WAN setup ≫ LAN setup: {lan} vs {wan}");
        assert!(wan < 5.0, "WAN setup stays small vs minutes-long transfers");
    }

    #[test]
    fn mathis_monotone_in_loss() {
        let mut p = PathProfile::wan();
        let base = p.stream_cap_bps();
        p.loss *= 4.0; // 2x sqrt -> half the rate (if loss-bound)
        let worse = p.stream_cap_bps();
        assert!(worse < base);
        assert!((base / worse - 2.0).abs() < 0.1);
    }

    #[test]
    fn loss_free_cap_excludes_mathis_term() {
        let p = PathProfile::wan();
        assert!(
            p.stream_cap_loss_free_bps() > p.stream_cap_bps(),
            "WAN is loss-bound, so dropping the Mathis term must raise the cap"
        );
        // LAN has no loss: both caps agree (endpoint-bound).
        let lan = PathProfile::lan();
        assert!((lan.stream_cap_loss_free_bps() - lan.stream_cap_bps()).abs() < 1.0);
    }

    #[test]
    fn handshake_latency_excludes_ramp() {
        let p = PathProfile::wan();
        assert!(p.handshake_latency_s() < p.setup_latency_s());
        assert!((p.handshake_latency_s() - calib::HANDSHAKE_RTTS * p.rtt_s).abs() < 1e-12);
    }

    #[test]
    fn zero_loss_falls_back_to_window() {
        let p = PathProfile {
            rtt_s: 0.058,
            loss: 0.0,
            window_bytes: 16.0 * 1024.0 * 1024.0,
            endpoint_bps: f64::INFINITY,
        };
        let cap = p.stream_cap_bps();
        assert!((cap - p.window_bytes / p.rtt_s).abs() < 1.0);
    }
}
