//! Calibration constants for the testbed model, with paper-derived
//! rationale. These are the *only* tuned numbers in the simulator; every
//! experiment outcome (90 Gbps LAN, 60 Gbps WAN, 2× queue ablation, 25 Gbps
//! VPN ceiling) must *emerge* from flows + topology + these constants.
//! See DESIGN.md §Calibration.

/// Fraction of raw NIC line rate available to application payload after
/// Ethernet/IP/TCP headers and HTCondor (CEDAR) framing. 100 Gbps NIC ⇒
/// ≈91 Gbps of goodput ceiling; the paper sustained 90.
pub const NIC_PROTOCOL_EFFICIENCY: f64 = 0.91;

/// TCP MSS in bytes (standard 1500 MTU minus headers).
pub const MSS_BYTES: f64 = 1460.0;

/// Mathis constant (√(3/2) for periodic loss, delayed ACKs off).
pub const MATHIS_C: f64 = 1.22;

/// Kernel TCP autotuning window ceiling (Linux default net.ipv4.tcp_rmem
/// max on the PRP nodes was 16 MiB-class).
pub const TCP_WINDOW_BYTES: f64 = 16.0 * 1024.0 * 1024.0;

/// Campus LAN round trip (same-building Nautilus nodes).
pub const LAN_RTT_S: f64 = 0.0002;

/// UCSD → New York measured RTT from the paper (§IV): "about 58 ms".
pub const WAN_RTT_S: f64 = 0.058;

/// Residual loss on the campus LAN: effectively zero.
pub const LAN_LOSS: f64 = 0.0;

/// Loss probability on the shared cross-US research backbone. Calibrated
/// so that one stream's Mathis rate ≈ 0.31 Gbps and ~195 concurrent
/// streams aggregate to the paper's observed ≈60 Gbps.
pub const WAN_LOSS: f64 = 5.2e-7;

/// Per-stream endpoint ceiling (bytes/sec): one shadow/starter pair's
/// single-threaded AES + TCP syscall path. HTCondor 9.0.1 with AES-NI
/// moves ≈1–2 GB/s per core; a shadow gets a share of the 8-core EPYC
/// 7252. 1.1 Gbps keeps 200 LAN streams NIC-bound (200 × 1.1 ≫ 93) while
/// a *single* stream can never saturate the NIC — matching HTCondor
/// operational experience.
pub const PER_STREAM_ENDPOINT_BPS: f64 = 1.1e9 / 8.0;

/// Shadow→starter connection setup: TCP + authentication + key exchange
/// round trips (HTCondor's security handshake is chatty — about 8 RTTs).
pub const HANDSHAKE_RTTS: f64 = 8.0;

/// Calico VPN overlay: per-node encap/decap processing ceiling observed by
/// the paper (§II): "limiting the throughput to about 25 Gbps".
pub const VPN_PROCESSING_GBPS: f64 = 25.0;

/// Background utilization of the shared WAN backbone (fraction of its
/// 100 Gbps): mean and stddev of the slowly-varying process, plus how often
/// it steps. The cross-US path is shared with other science traffic.
pub const WAN_BG_MEAN: f64 = 0.25;
pub const WAN_BG_SD: f64 = 0.08;
pub const WAN_BG_STEP_S: f64 = 30.0;

/// Mild LAN background (campus core is quiet but not silent).
pub const LAN_BG_MEAN: f64 = 0.02;
pub const LAN_BG_SD: f64 = 0.01;

/// Spinning-disk profile used by the transfer-queue default throttle
/// rationale: aggregate bandwidth and the per-extra-stream seek penalty.
pub const SPINNING_DISK_BPS: f64 = 180e6;
pub const NVME_DISK_BPS: f64 = 6e9;

/// Page-cache read bandwidth (memory-speed; effectively never the
/// bottleneck — the paper's hard-linked 2 GB file sits in cache).
pub const PAGE_CACHE_BPS: f64 = 30e9;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lan_endpoint_times_200_exceeds_nic() {
        // 200 streams × per-stream cap must exceed the NIC goodput ceiling,
        // otherwise the LAN test could never be NIC-bound as observed.
        let aggregate = 200.0 * PER_STREAM_ENDPOINT_BPS * 8.0 / 1e9;
        assert!(aggregate > 100.0 * NIC_PROTOCOL_EFFICIENCY);
    }

    #[test]
    fn wan_mathis_aggregate_near_60() {
        let per_stream = (MSS_BYTES / WAN_RTT_S) * (MATHIS_C / WAN_LOSS.sqrt());
        let agg_gbps = 195.0 * per_stream * 8.0 / 1e9;
        assert!(
            (55.0..75.0).contains(&agg_gbps),
            "calibration drifted: {agg_gbps} Gbps"
        );
    }

    #[test]
    fn single_stream_cannot_saturate_nic() {
        assert!(PER_STREAM_ENDPOINT_BPS * 8.0 / 1e9 < 10.0);
    }
}
