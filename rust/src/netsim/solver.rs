//! Rate solvers: how concurrent flows share the capacitated links.
//!
//! Two [`Solver`] implementations share one progressive-filling core:
//!
//! * [`FairShare`] — the steady-state max-min model (the default). Each
//!   flow is additionally constrained by its per-flow cap (its TCP
//!   throughput ceiling), modeled as a private pseudo-link. The algorithm
//!   is the textbook one: repeatedly find the most-constrained resource
//!   (the one with the smallest fair share among its unfrozen flows),
//!   freeze its flows at that share, subtract, repeat. Complexity
//!   O(iterations × flows × path-length); with the paper's ~200 concurrent
//!   transfers over ~20 resources a solve is microseconds (see
//!   `benches/netsim_solver.rs`).
//! * [`TcpDynamic`] — per-flow congestion windows evolved in virtual
//!   time: slow start (IW ≈ 10 MSS, doubling per RTT), AIMD congestion
//!   avoidance (+1 MSS per RTT, halve on loss), Bernoulli per-packet loss
//!   sampled per RTT from the path's loss rate. Each flow's *effective*
//!   cap becomes `min(cap_bps, cwnd/RTT)` and the same max-min filling
//!   distributes link capacity under those dynamic ceilings, so the
//!   RTT-dependent ramp the paper observes on its ~58 ms cross-US paths
//!   is reproduced instead of assumed away. In the zero-loss, zero-RTT
//!   limit (path RTT floors at the calibrated LAN value, where even the
//!   initial window sustains IW/RTT ≈ 73 MB/s) any flow whose fair share
//!   sits below that never sees its window bind, and the solver
//!   degenerates to [`FairShare`] exactly (property-tested in
//!   `tests/props.rs`).

use super::{calib, Flow, FlowId, Link};
use crate::util::units::SimTime;
use crate::util::Prng;
use std::collections::HashMap;

/// Reusable allocations for the solver hot path.
#[derive(Debug, Default)]
pub struct Scratch {
    rem: Vec<f64>,
    count: Vec<u32>,
    order: Vec<FlowId>,
    frozen: Vec<bool>,
    /// Effective per-flow cap for this solve (indexed like `order`).
    eff_cap: Vec<f64>,
}

impl Scratch {
    /// Fill `order` (deterministic flow order — HashMap iteration is not)
    /// and size the per-link/per-flow work arrays.
    fn prepare(&mut self, links: &[Link], flows: &HashMap<FlowId, Flow>) {
        self.order.clear();
        self.order.extend(flows.keys().copied());
        self.order.sort();

        self.rem.clear();
        self.rem.extend(links.iter().map(|l| l.capacity_bps));
        self.count.clear();
        self.count.resize(links.len(), 0);
        self.frozen.clear();
        self.frozen.resize(flows.len(), false);
        self.eff_cap.clear();
        self.eff_cap.resize(flows.len(), f64::INFINITY);

        for id in &self.order {
            for l in &flows[id].path {
                self.count[l.0] += 1;
            }
        }
    }
}

/// Compute max-min fair rates for `flows` over `links`, writing each
/// flow's `rate`. Caps come from each flow's own `cap_bps` (the
/// steady-state [`FairShare`] model).
pub fn solve(links: &[Link], flows: &mut HashMap<FlowId, Flow>, scratch: &mut Scratch) {
    if flows.is_empty() {
        return;
    }
    scratch.prepare(links, flows);
    for (fi, id) in scratch.order.iter().enumerate() {
        scratch.eff_cap[fi] = flows[id].cap_bps;
    }
    fill(flows, scratch);
}

/// Progressive filling over prepared scratch state: distribute link
/// capacity max-min fairly, each flow ceilinged at `scratch.eff_cap`.
/// Callers must have run [`Scratch::prepare`] and set `eff_cap`.
fn fill(flows: &mut HashMap<FlowId, Flow>, scratch: &mut Scratch) {
    let mut unfrozen = scratch.order.len();
    // Progressive filling: each iteration freezes at least one flow.
    while unfrozen > 0 {
        // Smallest fair share among saturable links and flow caps.
        let mut limit = f64::INFINITY;
        for (i, &rem) in scratch.rem.iter().enumerate() {
            if scratch.count[i] > 0 {
                limit = limit.min(rem / scratch.count[i] as f64);
            }
        }
        let mut cap_limited = false;
        for fi in 0..scratch.order.len() {
            if !scratch.frozen[fi] {
                let cap = scratch.eff_cap[fi];
                if cap <= limit {
                    limit = cap;
                    cap_limited = true;
                }
            }
        }
        if !limit.is_finite() {
            // No constraining resource at all: flows are unbounded; pick a
            // degenerate huge rate to make progress deterministically.
            limit = 1e15;
        }

        // Freeze: (a) flows whose cap equals the limit; (b) flows crossing
        // a link that is exactly exhausted at this fair share.
        let mut froze_any = false;
        for (fi, id) in scratch.order.iter().enumerate() {
            if scratch.frozen[fi] {
                continue;
            }
            let f = &flows[id];
            let cap = scratch.eff_cap[fi];
            let at_cap = cap_limited && cap <= limit * (1.0 + 1e-12);
            let on_bottleneck = f.path.iter().any(|l| {
                scratch.count[l.0] > 0
                    && scratch.rem[l.0] / scratch.count[l.0] as f64 <= limit * (1.0 + 1e-9)
            });
            if at_cap || on_bottleneck {
                let rate = limit.min(cap);
                let path = f.path.clone();
                flows.get_mut(id).unwrap().rate = rate;
                scratch.frozen[fi] = true;
                froze_any = true;
                unfrozen -= 1;
                for l in &path {
                    scratch.rem[l.0] = (scratch.rem[l.0] - rate).max(0.0);
                    scratch.count[l.0] -= 1;
                }
            }
        }
        debug_assert!(froze_any, "progressive filling must make progress");
        if !froze_any {
            // Defensive: freeze everything at the limit to avoid a hang.
            for (fi, id) in scratch.order.iter().enumerate() {
                if !scratch.frozen[fi] {
                    flows.get_mut(id).unwrap().rate = limit.min(scratch.eff_cap[fi]);
                    scratch.frozen[fi] = true;
                    unfrozen -= 1;
                }
            }
        }
    }
}

/// A rate solver: given the current instant, links, and active flows,
/// write each flow's `rate`. Dynamic solvers additionally publish the
/// next virtual instant at which rates must be re-solved even though no
/// flow arrived or departed ([`Solver::next_update`]).
pub trait Solver: std::fmt::Debug + Send {
    /// Short machine-readable name stamped into reports ("fair-share",
    /// "tcp-dynamic").
    fn label(&self) -> &'static str;

    /// Recompute every flow's `rate` as of `now`.
    fn solve(
        &mut self,
        now: SimTime,
        links: &[Link],
        flows: &mut HashMap<FlowId, Flow>,
        scratch: &mut Scratch,
    );

    /// Next instant (strictly after `now`) at which this solver wants to
    /// re-run with no topology change — `None` for steady-state solvers
    /// and once every window has saturated.
    fn next_update(&self, _now: SimTime) -> Option<SimTime> {
        None
    }
}

/// Which solver to install — the `SOLVER` knob / `--solver` flag, parsed
/// from its report label.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolverKind {
    FairShare,
    TcpDynamic,
}

impl SolverKind {
    pub fn parse(s: &str) -> Option<SolverKind> {
        match s.to_ascii_lowercase().as_str() {
            "fair-share" | "fairshare" | "fair_share" => Some(SolverKind::FairShare),
            "tcp-dynamic" | "tcpdynamic" | "tcp_dynamic" | "tcp" => Some(SolverKind::TcpDynamic),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            SolverKind::FairShare => "fair-share",
            SolverKind::TcpDynamic => "tcp-dynamic",
        }
    }

    /// Construct the solver. `seed` feeds [`TcpDynamic`]'s per-flow loss
    /// sampling (ignored by [`FairShare`]).
    pub fn build(&self, seed: u64) -> Box<dyn Solver> {
        match self {
            SolverKind::FairShare => Box::new(FairShare),
            SolverKind::TcpDynamic => Box::new(TcpDynamic::new(seed)),
        }
    }
}

impl Default for SolverKind {
    fn default() -> Self {
        SolverKind::FairShare
    }
}

/// The steady-state max-min solver (default): flows jump to their
/// fair-share rate instantly; caps are static.
#[derive(Debug, Default, Clone, Copy)]
pub struct FairShare;

impl Solver for FairShare {
    fn label(&self) -> &'static str {
        "fair-share"
    }

    fn solve(
        &mut self,
        _now: SimTime,
        links: &[Link],
        flows: &mut HashMap<FlowId, Flow>,
        scratch: &mut Scratch,
    ) {
        solve(links, flows, scratch);
    }
}

/// TCP initial window (RFC 6928): 10 segments.
const INIT_CWND_BYTES: f64 = 10.0 * calib::MSS_BYTES;
/// Congestion-avoidance flows re-solve every this many RTTs (slow-start
/// flows every RTT) — coarse enough to keep the event count linear in
/// virtual time, fine enough that AIMD sawtooth averages out per bin.
const CA_TICK_RTTS: f64 = 8.0;
/// Floor on the re-solve cadence so sub-millisecond LAN RTTs cannot
/// flood the event loop.
const MIN_TICK_S: f64 = 1e-4;
/// Cap on per-flow RTT steps replayed in one solve (a clamp, not a
/// cadence: the update schedule keeps elapsed time ≈ one tick).
const MAX_STEPS_PER_SOLVE: u64 = 256;

/// Per-flow congestion state evolved by [`TcpDynamic`].
#[derive(Debug)]
struct TcpFlowState {
    /// Congestion window in bytes.
    cwnd: f64,
    /// Slow-start threshold in bytes.
    ssthresh: f64,
    /// Path round trip (sum of link RTTs, floored at the LAN RTT).
    rtt_s: f64,
    /// Path loss probability (per packet).
    loss: f64,
    /// Instant up to which window dynamics have been replayed.
    last: SimTime,
    slow_start: bool,
    /// True once the window can no longer bind (zero-loss path, cwnd at
    /// the kernel ceiling or past the flow's static cap): stop ticking.
    saturated: bool,
    prng: Prng,
}

/// Dynamic TCP solver: slow start + AIMD + Bernoulli loss per flow,
/// layered under the same max-min filling as [`FairShare`].
#[derive(Debug)]
pub struct TcpDynamic {
    seed: u64,
    states: HashMap<FlowId, TcpFlowState>,
    pending: Option<SimTime>,
}

impl TcpDynamic {
    pub fn new(seed: u64) -> TcpDynamic {
        TcpDynamic {
            seed,
            states: HashMap::new(),
            pending: None,
        }
    }

    /// Path RTT / loss of a flow from its links' annotations. RTT floors
    /// at the calibrated LAN RTT so a zero-RTT topology still has a
    /// well-defined (and instantly-saturating) window dynamic.
    fn path_profile(links: &[Link], f: &Flow) -> (f64, f64) {
        let rtt: f64 = f.path.iter().map(|l| links[l.0].rtt_s).sum();
        let loss: f64 = f.path.iter().map(|l| links[l.0].loss).sum();
        (rtt.max(calib::LAN_RTT_S), loss.clamp(0.0, 1.0))
    }

    /// Replay window dynamics for one flow up to `now`, one RTT per step.
    fn evolve(s: &mut TcpFlowState, now: SimTime) {
        if s.saturated {
            return;
        }
        let elapsed = now.since(s.last).as_secs_f64();
        let whole_rtts = (elapsed / s.rtt_s).floor() as u64;
        if whole_rtts == 0 {
            return;
        }
        let steps = whole_rtts.min(MAX_STEPS_PER_SOLVE);
        for _ in 0..steps {
            let packets = (s.cwnd / calib::MSS_BYTES).max(1.0);
            // Probability at least one of this RTT's packets is lost.
            let p_event = if s.loss > 0.0 {
                1.0 - (1.0 - s.loss).powf(packets)
            } else {
                0.0
            };
            if p_event > 0.0 && s.prng.next_f64() < p_event {
                // Loss event: multiplicative decrease, leave slow start.
                s.ssthresh = (s.cwnd / 2.0).max(2.0 * calib::MSS_BYTES);
                s.cwnd = s.ssthresh;
                s.slow_start = false;
            } else if s.slow_start {
                s.cwnd = (s.cwnd * 2.0).min(s.ssthresh.min(calib::TCP_WINDOW_BYTES));
                if s.cwnd >= s.ssthresh || s.cwnd >= calib::TCP_WINDOW_BYTES {
                    s.slow_start = false;
                }
            } else {
                // Additive increase: one MSS per RTT.
                s.cwnd = (s.cwnd + calib::MSS_BYTES).min(calib::TCP_WINDOW_BYTES);
            }
        }
        s.last = if whole_rtts > steps {
            now // clamped replay: drop sub-RTT phase rather than lag behind
        } else {
            s.last + SimTime((steps as f64 * s.rtt_s * 1e9) as u64)
        };
    }
}

impl Solver for TcpDynamic {
    fn label(&self) -> &'static str {
        "tcp-dynamic"
    }

    fn solve(
        &mut self,
        now: SimTime,
        links: &[Link],
        flows: &mut HashMap<FlowId, Flow>,
        scratch: &mut Scratch,
    ) {
        self.states.retain(|id, _| flows.contains_key(id));
        if flows.is_empty() {
            self.pending = None;
            return;
        }
        scratch.prepare(links, flows);
        let mut min_tick = f64::INFINITY;
        for (fi, id) in scratch.order.iter().enumerate() {
            let f = &flows[id];
            let seed = self.seed ^ id.0.wrapping_mul(0x9e37_79b9_7f4a_7c15);
            let s = self.states.entry(*id).or_insert_with(|| {
                let (rtt_s, loss) = TcpDynamic::path_profile(links, f);
                TcpFlowState {
                    cwnd: INIT_CWND_BYTES,
                    ssthresh: f64::INFINITY,
                    rtt_s,
                    loss,
                    last: f.started,
                    slow_start: true,
                    saturated: false,
                    prng: Prng::new(seed),
                }
            });
            TcpDynamic::evolve(s, now);
            let window_limit = s.cwnd / s.rtt_s;
            // A zero-loss window only grows: once it stops binding (flow
            // cap or kernel ceiling reached) it never binds again.
            if s.loss == 0.0
                && (window_limit >= f.cap_bps || s.cwnd >= calib::TCP_WINDOW_BYTES)
            {
                s.saturated = true;
            }
            scratch.eff_cap[fi] = f.cap_bps.min(window_limit);
            if !s.saturated {
                let tick = if s.slow_start {
                    s.rtt_s
                } else {
                    CA_TICK_RTTS * s.rtt_s
                };
                min_tick = min_tick.min(tick.max(MIN_TICK_S));
            }
        }
        fill(flows, scratch);
        self.pending = if min_tick.is_finite() {
            Some(now + SimTime((min_tick * 1e9).ceil() as u64))
        } else {
            None
        };
    }

    fn next_update(&self, now: SimTime) -> Option<SimTime> {
        // Strictly in the future: an update at/before `now` would stall
        // the event loop on zero-length advances.
        self.pending.map(|t| t.max(now + SimTime(1)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{Link, LinkId, NetSim};
    use crate::util::units::Gbps;
    use crate::util::Prng;

    fn mklink(cap_gbps: f64) -> Link {
        Link {
            name: "l".into(),
            capacity_bps: Gbps(cap_gbps).bytes_per_sec(),
            rtt_s: 0.0,
            loss: 0.0,
            bytes_carried: 0.0,
            monitor: None,
        }
    }

    fn mkflow(path: Vec<usize>, cap_bps: f64) -> Flow {
        Flow {
            path: path.into_iter().map(LinkId).collect(),
            remaining: 1e12,
            total: 1e12,
            cap_bps,
            rate: 0.0,
            started: crate::util::units::SimTime::ZERO,
        }
    }

    fn run(links: &[Link], flow_list: Vec<Flow>) -> Vec<f64> {
        let mut flows = HashMap::new();
        for (i, f) in flow_list.into_iter().enumerate() {
            flows.insert(FlowId(i as u64), f);
        }
        let mut scratch = Scratch::default();
        solve(links, &mut flows, &mut scratch);
        let mut out: Vec<(FlowId, f64)> = flows.into_iter().map(|(id, f)| (id, f.rate)).collect();
        out.sort_by_key(|(id, _)| *id);
        out.into_iter().map(|(_, r)| r).collect()
    }

    #[test]
    fn classic_three_flow_example() {
        // Two links: L0 cap 1, L1 cap 2 (in GB/s-ish units via Gbps(8)=1GB/s).
        // f0 uses L0, f1 uses L0+L1, f2 uses L1.
        // Max-min: f0=f1=0.5 on L0; f2 = 2-0.5 = 1.5.
        let links = vec![mklink(8.0), mklink(16.0)];
        let rates = run(
            &links,
            vec![
                mkflow(vec![0], f64::INFINITY),
                mkflow(vec![0, 1], f64::INFINITY),
                mkflow(vec![1], f64::INFINITY),
            ],
        );
        assert!((rates[0] - 0.5e9).abs() < 1.0);
        assert!((rates[1] - 0.5e9).abs() < 1.0);
        assert!((rates[2] - 1.5e9).abs() < 1.0);
    }

    #[test]
    fn caps_create_second_round() {
        // One 1 GB/s link, 3 flows; one capped at 0.1 GB/s.
        // Max-min: capped=0.1, others (1-0.1)/2 = 0.45.
        let links = vec![mklink(8.0)];
        let rates = run(
            &links,
            vec![
                mkflow(vec![0], 0.1e9),
                mkflow(vec![0], f64::INFINITY),
                mkflow(vec![0], f64::INFINITY),
            ],
        );
        assert!((rates[0] - 0.1e9).abs() < 1.0);
        assert!((rates[1] - 0.45e9).abs() < 1.0);
        assert!((rates[2] - 0.45e9).abs() < 1.0);
    }

    #[test]
    fn all_capped_below_fair_share() {
        let links = vec![mklink(80.0)]; // 10 GB/s
        let rates = run(&links, (0..5).map(|_| mkflow(vec![0], 0.2e9)).collect());
        for r in rates {
            assert!((r - 0.2e9).abs() < 1.0);
        }
    }

    #[test]
    fn unbounded_flows_get_finite_rate() {
        // No link on path (empty path is not allowed by NetSim, but the
        // solver itself must not hang if caps are infinite and links empty).
        let links = vec![mklink(8.0)];
        let rates = run(&links, vec![mkflow(vec![0], f64::INFINITY)]);
        assert!((rates[0] - 1e9).abs() < 1.0);
    }

    /// Invariants, property-tested over random topologies:
    ///  1. capacity: sum of rates on each link <= cap (+eps)
    ///  2. cap: each flow rate <= its cap (+eps)
    ///  3. bottleneck: every flow is at its cap OR crosses a saturated
    ///     link where it has (weakly) the largest rate — the defining
    ///     property of max-min fairness.
    #[test]
    fn maxmin_invariants_random() {
        crate::util::testkit::check("maxmin-invariants", 60, |g| {
            let nlinks = g.rng.range_usize(1, 8);
            let links: Vec<Link> = (0..nlinks)
                .map(|_| mklink(g.rng.range_f64(1.0, 100.0)))
                .collect();
            let nflows = g.rng.range_usize(1, 40);
            let mut flows = HashMap::new();
            for i in 0..nflows {
                let plen = g.rng.range_usize(1, nlinks.min(4));
                let mut path: Vec<usize> = (0..nlinks).collect();
                g.rng.shuffle(&mut path);
                path.truncate(plen);
                let cap = if g.rng.next_f64() < 0.4 {
                    g.rng.range_f64(0.01e9, 2e9)
                } else {
                    f64::INFINITY
                };
                flows.insert(FlowId(i as u64), mkflow(path, cap));
            }
            let mut scratch = Scratch::default();
            solve(&links, &mut flows, &mut scratch);

            let eps = 1e-3;
            // (1) link capacity respected
            for (li, l) in links.iter().enumerate() {
                let used: f64 = flows
                    .values()
                    .filter(|f| f.path.iter().any(|x| x.0 == li))
                    .map(|f| f.rate)
                    .sum();
                assert!(
                    used <= l.capacity_bps * (1.0 + 1e-9) + eps,
                    "link {li} over capacity: {used} > {}",
                    l.capacity_bps
                );
            }
            // (2) flow caps respected, rates positive
            for f in flows.values() {
                assert!(f.rate <= f.cap_bps * (1.0 + 1e-9) + eps);
                assert!(f.rate > 0.0, "every flow gets a positive rate");
            }
            // (3) bottleneck property
            for (id, f) in &flows {
                if f.rate >= f.cap_bps * (1.0 - 1e-9) {
                    continue; // at own cap
                }
                let has_bottleneck = f.path.iter().any(|l| {
                    let on_link: Vec<f64> = flows
                        .values()
                        .filter(|g2| g2.path.contains(l))
                        .map(|g2| g2.rate)
                        .collect();
                    let used: f64 = on_link.iter().sum();
                    let saturated = used >= links[l.0].capacity_bps * (1.0 - 1e-6) - eps;
                    let max_other = on_link.iter().cloned().fold(0.0, f64::max);
                    saturated && f.rate >= max_other * (1.0 - 1e-6) - eps
                });
                assert!(
                    has_bottleneck,
                    "flow {id:?} rate {} has no bottleneck link",
                    f.rate
                );
            }
        });
    }

    #[test]
    fn solver_deterministic_across_runs() {
        let mut rates1 = None;
        for _ in 0..2 {
            let mut net = NetSim::new();
            let a = net.add_link("a", Gbps(10.0));
            let b = net.add_link("b", Gbps(20.0));
            let mut prng = Prng::new(99);
            let mut ids = Vec::new();
            for _ in 0..50 {
                let path = if prng.next_f64() < 0.5 {
                    vec![a]
                } else {
                    vec![a, b]
                };
                ids.push(net.start_flow(path, 1e9, prng.range_f64(0.05e9, 1e9)));
            }
            let rates: Vec<f64> = ids.iter().map(|id| net.flow_rate(*id).unwrap()).collect();
            match &rates1 {
                None => rates1 = Some(rates),
                Some(prev) => assert_eq!(prev, &rates),
            }
        }
    }

    #[test]
    fn solver_kind_parse_and_label_roundtrip() {
        for kind in [SolverKind::FairShare, SolverKind::TcpDynamic] {
            assert_eq!(SolverKind::parse(kind.label()), Some(kind));
        }
        assert_eq!(SolverKind::parse("tcp"), Some(SolverKind::TcpDynamic));
        assert_eq!(SolverKind::parse("nope"), None);
        assert_eq!(SolverKind::default(), SolverKind::FairShare);
    }

    /// On a long-RTT path the dynamic solver's early rate is window-bound
    /// far below the link, then ramps toward it; the steady-state solver
    /// starts at full rate.
    #[test]
    fn tcp_dynamic_slow_start_ramps() {
        let mut net = NetSim::new();
        let l = net.add_link("wan", Gbps(8.0)); // 1 GB/s
        net.set_link_profile(l, 0.1, 0.0); // 100 ms RTT, lossless
        net.set_solver(SolverKind::TcpDynamic.build(7));
        let f = net.start_flow(vec![l], 1e12, f64::INFINITY);
        let r0 = net.flow_rate(f).unwrap();
        assert!(
            (r0 - INIT_CWND_BYTES / 0.1).abs() < 1.0,
            "first RTT is IW-bound: got {r0}"
        );
        // Step through solver updates: the rate must double per RTT until
        // the 16 MiB kernel window ceiling (160 MB/s at 100 ms) binds.
        let mut last = r0;
        for _ in 0..16 {
            let Some(t) = net.next_completion() else { break };
            net.advance_to(t);
            let r = net.flow_rate(f).unwrap();
            assert!(r >= last - 1.0, "ramp is monotone on a lossless path");
            last = r;
        }
        let ceiling = calib::TCP_WINDOW_BYTES / 0.1;
        assert!(
            (last - ceiling).abs() < 1.0,
            "ramp converges to window/RTT = {ceiling}, got {last}"
        );
    }

    /// Loss keeps the window (and thus the rate) strictly below the
    /// lossless ceiling — the Mathis mechanism, emerging from sampling.
    #[test]
    fn tcp_dynamic_loss_limits_rate() {
        let run_with = |loss: f64| -> f64 {
            let mut net = NetSim::new();
            let l = net.add_link("wan", Gbps(80.0));
            net.set_link_profile(l, 0.058, loss);
            net.set_solver(SolverKind::TcpDynamic.build(11));
            let f = net.start_flow(vec![l], 5e10, f64::INFINITY);
            let mut rates = Vec::new();
            for _ in 0..200 {
                let Some(t) = net.next_completion() else { break };
                net.advance_to(t);
                if net.completed().contains(&f) {
                    break;
                }
                rates.push(net.flow_rate(f).unwrap());
            }
            let tail = &rates[rates.len() / 2..];
            tail.iter().sum::<f64>() / tail.len() as f64
        };
        let lossless = run_with(0.0);
        let lossy = run_with(1e-4);
        assert!(
            lossy < lossless * 0.5,
            "1e-4 loss must sit well below the lossless rate: {lossy} vs {lossless}"
        );
    }

    /// Per-flow PRNG streams make the loss process deterministic for a
    /// given seed and event sequence.
    #[test]
    fn tcp_dynamic_deterministic_across_runs() {
        let run_once = || -> Vec<f64> {
            let mut net = NetSim::new();
            let l = net.add_link("wan", Gbps(8.0));
            net.set_link_profile(l, 0.05, 1e-5);
            net.set_solver(SolverKind::TcpDynamic.build(42));
            let f1 = net.start_flow(vec![l], 1e11, f64::INFINITY);
            let f2 = net.start_flow(vec![l], 1e11, f64::INFINITY);
            let mut rates = Vec::new();
            for _ in 0..50 {
                let Some(t) = net.next_completion() else { break };
                net.advance_to(t);
                rates.push(net.flow_rate(f1).unwrap_or(0.0));
                rates.push(net.flow_rate(f2).unwrap_or(0.0));
            }
            rates
        };
        assert_eq!(run_once(), run_once());
    }
}
