//! Max-min fair-share solver (progressive filling / water-filling).
//!
//! Each flow is additionally constrained by its per-flow cap (its TCP
//! throughput ceiling), modeled as a private pseudo-link. The algorithm is
//! the textbook one: repeatedly find the most-constrained resource (the one
//! with the smallest fair share among its unfrozen flows), freeze its flows
//! at that share, subtract, repeat. Complexity O(iterations × flows ×
//! path-length); with the paper's ~200 concurrent transfers over ~20
//! resources a solve is microseconds (see `benches/netsim_solver.rs`).

use super::{Flow, FlowId, Link};
use std::collections::HashMap;

/// Reusable allocations for the solver hot path.
#[derive(Debug, Default)]
pub struct Scratch {
    rem: Vec<f64>,
    count: Vec<u32>,
    order: Vec<FlowId>,
    frozen: Vec<bool>,
}

/// Compute max-min fair rates for `flows` over `links`, writing each
/// flow's `rate`.
pub fn solve(links: &[Link], flows: &mut HashMap<FlowId, Flow>, scratch: &mut Scratch) {
    let n = flows.len();
    if n == 0 {
        return;
    }

    // Deterministic flow order (HashMap iteration is not).
    scratch.order.clear();
    scratch.order.extend(flows.keys().copied());
    scratch.order.sort();

    scratch.rem.clear();
    scratch.rem.extend(links.iter().map(|l| l.capacity_bps));
    scratch.count.clear();
    scratch.count.resize(links.len(), 0);
    scratch.frozen.clear();
    scratch.frozen.resize(n, false);

    for id in &scratch.order {
        for l in &flows[id].path {
            scratch.count[l.0] += 1;
        }
    }

    let mut unfrozen = n;
    // Progressive filling: each iteration freezes at least one flow.
    while unfrozen > 0 {
        // Smallest fair share among saturable links and flow caps.
        let mut limit = f64::INFINITY;
        for (i, &rem) in scratch.rem.iter().enumerate() {
            if scratch.count[i] > 0 {
                limit = limit.min(rem / scratch.count[i] as f64);
            }
        }
        let mut cap_limited = false;
        for (fi, id) in scratch.order.iter().enumerate() {
            if !scratch.frozen[fi] {
                let cap = flows[id].cap_bps;
                if cap <= limit {
                    limit = cap;
                    cap_limited = true;
                }
            }
        }
        if !limit.is_finite() {
            // No constraining resource at all: flows are unbounded; pick a
            // degenerate huge rate to make progress deterministically.
            limit = 1e15;
        }

        // Freeze: (a) flows whose cap equals the limit; (b) flows crossing
        // a link that is exactly exhausted at this fair share.
        let mut froze_any = false;
        for (fi, id) in scratch.order.iter().enumerate() {
            if scratch.frozen[fi] {
                continue;
            }
            let f = &flows[id];
            let at_cap = cap_limited && f.cap_bps <= limit * (1.0 + 1e-12);
            let on_bottleneck = f.path.iter().any(|l| {
                scratch.count[l.0] > 0
                    && scratch.rem[l.0] / scratch.count[l.0] as f64 <= limit * (1.0 + 1e-9)
            });
            if at_cap || on_bottleneck {
                let rate = limit.min(f.cap_bps);
                let path = f.path.clone();
                flows.get_mut(id).unwrap().rate = rate;
                scratch.frozen[fi] = true;
                froze_any = true;
                unfrozen -= 1;
                for l in &path {
                    scratch.rem[l.0] = (scratch.rem[l.0] - rate).max(0.0);
                    scratch.count[l.0] -= 1;
                }
            }
        }
        debug_assert!(froze_any, "progressive filling must make progress");
        if !froze_any {
            // Defensive: freeze everything at the limit to avoid a hang.
            for (fi, id) in scratch.order.iter().enumerate() {
                if !scratch.frozen[fi] {
                    flows.get_mut(id).unwrap().rate = limit.min(flows[id].cap_bps);
                    scratch.frozen[fi] = true;
                    unfrozen -= 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{Link, LinkId, NetSim};
    use crate::util::units::Gbps;
    use crate::util::Prng;

    fn mklink(cap_gbps: f64) -> Link {
        Link {
            name: "l".into(),
            capacity_bps: Gbps(cap_gbps).bytes_per_sec(),
            bytes_carried: 0.0,
            monitor: None,
        }
    }

    fn mkflow(path: Vec<usize>, cap_bps: f64) -> Flow {
        Flow {
            path: path.into_iter().map(LinkId).collect(),
            remaining: 1e12,
            total: 1e12,
            cap_bps,
            rate: 0.0,
            started: crate::util::units::SimTime::ZERO,
        }
    }

    fn run(links: &[Link], flow_list: Vec<Flow>) -> Vec<f64> {
        let mut flows = HashMap::new();
        for (i, f) in flow_list.into_iter().enumerate() {
            flows.insert(FlowId(i as u64), f);
        }
        let mut scratch = Scratch::default();
        solve(links, &mut flows, &mut scratch);
        let mut out: Vec<(FlowId, f64)> = flows.into_iter().map(|(id, f)| (id, f.rate)).collect();
        out.sort_by_key(|(id, _)| *id);
        out.into_iter().map(|(_, r)| r).collect()
    }

    #[test]
    fn classic_three_flow_example() {
        // Two links: L0 cap 1, L1 cap 2 (in GB/s-ish units via Gbps(8)=1GB/s).
        // f0 uses L0, f1 uses L0+L1, f2 uses L1.
        // Max-min: f0=f1=0.5 on L0; f2 = 2-0.5 = 1.5.
        let links = vec![mklink(8.0), mklink(16.0)];
        let rates = run(
            &links,
            vec![
                mkflow(vec![0], f64::INFINITY),
                mkflow(vec![0, 1], f64::INFINITY),
                mkflow(vec![1], f64::INFINITY),
            ],
        );
        assert!((rates[0] - 0.5e9).abs() < 1.0);
        assert!((rates[1] - 0.5e9).abs() < 1.0);
        assert!((rates[2] - 1.5e9).abs() < 1.0);
    }

    #[test]
    fn caps_create_second_round() {
        // One 1 GB/s link, 3 flows; one capped at 0.1 GB/s.
        // Max-min: capped=0.1, others (1-0.1)/2 = 0.45.
        let links = vec![mklink(8.0)];
        let rates = run(
            &links,
            vec![
                mkflow(vec![0], 0.1e9),
                mkflow(vec![0], f64::INFINITY),
                mkflow(vec![0], f64::INFINITY),
            ],
        );
        assert!((rates[0] - 0.1e9).abs() < 1.0);
        assert!((rates[1] - 0.45e9).abs() < 1.0);
        assert!((rates[2] - 0.45e9).abs() < 1.0);
    }

    #[test]
    fn all_capped_below_fair_share() {
        let links = vec![mklink(80.0)]; // 10 GB/s
        let rates = run(
            &links,
            (0..5).map(|_| mkflow(vec![0], 0.2e9)).collect(),
        );
        for r in rates {
            assert!((r - 0.2e9).abs() < 1.0);
        }
    }

    #[test]
    fn unbounded_flows_get_finite_rate() {
        // No link on path (empty path is not allowed by NetSim, but the
        // solver itself must not hang if caps are infinite and links empty).
        let links = vec![mklink(8.0)];
        let rates = run(&links, vec![mkflow(vec![0], f64::INFINITY)]);
        assert!((rates[0] - 1e9).abs() < 1.0);
    }

    /// Invariants, property-tested over random topologies:
    ///  1. capacity: sum of rates on each link <= cap (+eps)
    ///  2. cap: each flow rate <= its cap (+eps)
    ///  3. bottleneck: every flow is at its cap OR crosses a saturated
    ///     link where it has (weakly) the largest rate — the defining
    ///     property of max-min fairness.
    #[test]
    fn maxmin_invariants_random() {
        crate::util::testkit::check("maxmin-invariants", 60, |g| {
            let nlinks = g.rng.range_usize(1, 8);
            let links: Vec<Link> = (0..nlinks)
                .map(|_| mklink(g.rng.range_f64(1.0, 100.0)))
                .collect();
            let nflows = g.rng.range_usize(1, 40);
            let mut flows = HashMap::new();
            for i in 0..nflows {
                let plen = g.rng.range_usize(1, nlinks.min(4));
                let mut path: Vec<usize> = (0..nlinks).collect();
                g.rng.shuffle(&mut path);
                path.truncate(plen);
                let cap = if g.rng.next_f64() < 0.4 {
                    g.rng.range_f64(0.01e9, 2e9)
                } else {
                    f64::INFINITY
                };
                flows.insert(FlowId(i as u64), mkflow(path, cap));
            }
            let mut scratch = Scratch::default();
            solve(&links, &mut flows, &mut scratch);

            let eps = 1e-3;
            // (1) link capacity respected
            for (li, l) in links.iter().enumerate() {
                let used: f64 = flows
                    .values()
                    .filter(|f| f.path.iter().any(|x| x.0 == li))
                    .map(|f| f.rate)
                    .sum();
                assert!(
                    used <= l.capacity_bps * (1.0 + 1e-9) + eps,
                    "link {li} over capacity: {used} > {}",
                    l.capacity_bps
                );
            }
            // (2) flow caps respected, rates positive
            for f in flows.values() {
                assert!(f.rate <= f.cap_bps * (1.0 + 1e-9) + eps);
                assert!(f.rate > 0.0, "every flow gets a positive rate");
            }
            // (3) bottleneck property
            for (id, f) in &flows {
                if f.rate >= f.cap_bps * (1.0 - 1e-9) {
                    continue; // at own cap
                }
                let has_bottleneck = f.path.iter().any(|l| {
                    let on_link: Vec<f64> = flows
                        .values()
                        .filter(|g2| g2.path.contains(l))
                        .map(|g2| g2.rate)
                        .collect();
                    let used: f64 = on_link.iter().sum();
                    let saturated = used >= links[l.0].capacity_bps * (1.0 - 1e-6) - eps;
                    let max_other = on_link.iter().cloned().fold(0.0, f64::max);
                    saturated && f.rate >= max_other * (1.0 - 1e-6) - eps
                });
                assert!(
                    has_bottleneck,
                    "flow {id:?} rate {} has no bottleneck link",
                    f.rate
                );
            }
        });
    }

    #[test]
    fn solver_deterministic_across_runs() {
        let mut rates1 = None;
        for _ in 0..2 {
            let mut net = NetSim::new();
            let a = net.add_link("a", Gbps(10.0));
            let b = net.add_link("b", Gbps(20.0));
            let mut prng = Prng::new(99);
            let mut ids = Vec::new();
            for _ in 0..50 {
                let path = if prng.next_f64() < 0.5 {
                    vec![a]
                } else {
                    vec![a, b]
                };
                ids.push(net.start_flow(path, 1e9, prng.range_f64(0.05e9, 1e9)));
            }
            let rates: Vec<f64> = ids.iter().map(|id| net.flow_rate(*id).unwrap()).collect();
            match &rates1 {
                None => rates1 = Some(rates),
                Some(prev) => assert_eq!(prev, &rates),
            }
        }
    }
}
