//! Fluid-flow network simulator: the stand-in for the paper's testbed.
//!
//! The PRP testbed (100 Gbps NICs at UCSD, cross-US research backbone,
//! Calico VPN overlay) is modeled as a set of capacitated *resources*
//! (NIC tx/rx, backbone segments, per-node VPN-processing capacity) shared
//! by *flows* under max-min fairness — the standard flow-level abstraction
//! for aggregate TCP behaviour (cf. SimGrid). Each HTCondor file transfer
//! is one flow whose path is the sequence of resources it crosses, with a
//! per-flow rate cap from the TCP model ([`tcp`]).
//!
//! The simulator is *event-driven*: between flow arrivals/departures and
//! capacity changes, rates are constant, so progress integrates exactly.
//! [`NetSim::next_completion`] tells the experiment engine when the next
//! flow will finish under current rates.

pub mod calib;
pub mod solver;
pub mod tcp;
pub mod topology;

use crate::metrics::BinSeries;
use crate::util::units::{Gbps, SimTime};
use std::collections::HashMap;

/// Index of a capacitated resource (NIC direction, backbone hop, VPN CPU).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LinkId(pub usize);

/// Identifier of an active flow.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FlowId(pub u64);

#[derive(Debug, Clone)]
pub struct Link {
    pub name: String,
    /// Capacity in bytes/sec (already derated by protocol efficiency).
    pub capacity_bps: f64,
    /// Round-trip contribution of this hop in seconds (dynamic solvers
    /// sum it over a flow's path; 0 for same-rack hops).
    pub rtt_s: f64,
    /// Per-packet loss probability contributed by this hop.
    pub loss: f64,
    /// Cumulative bytes carried (for monitors / figures).
    pub bytes_carried: f64,
    /// Optional throughput monitor (binned timeseries).
    pub monitor: Option<BinSeries>,
}

#[derive(Debug, Clone)]
pub struct Flow {
    pub path: Vec<LinkId>,
    pub remaining: f64,
    pub total: f64,
    /// Per-flow rate cap (bytes/sec) from the TCP model.
    pub cap_bps: f64,
    /// Current allocated rate (bytes/sec).
    pub rate: f64,
    pub started: SimTime,
}

/// Statistics returned when a flow completes or is inspected.
#[derive(Debug, Clone, Copy)]
pub struct FlowStats {
    pub bytes: f64,
    pub started: SimTime,
    pub finished: SimTime,
}

impl FlowStats {
    pub fn duration(&self) -> SimTime {
        self.finished.since(self.started)
    }
    pub fn mean_rate_bps(&self) -> f64 {
        let d = self.duration().as_secs_f64();
        if d > 0.0 {
            self.bytes / d
        } else {
            f64::INFINITY
        }
    }
}

#[derive(Debug)]
pub struct NetSim {
    links: Vec<Link>,
    flows: HashMap<FlowId, Flow>,
    next_flow: u64,
    now: SimTime,
    /// True when flow rates are stale and must be re-solved.
    dirty: bool,
    /// Incremented on every topology/flow change; used by the engine to
    /// invalidate stale completion events.
    pub epoch: u64,
    solver: Box<dyn solver::Solver>,
    /// Next solver-requested re-solve instant (dynamic solvers only).
    pending_update: Option<SimTime>,
    solver_scratch: solver::Scratch,
}

impl Default for NetSim {
    fn default() -> Self {
        Self::new()
    }
}

impl NetSim {
    pub fn new() -> NetSim {
        NetSim {
            links: Vec::new(),
            flows: HashMap::new(),
            next_flow: 0,
            now: SimTime::ZERO,
            dirty: false,
            epoch: 0,
            solver: Box::new(solver::FairShare),
            pending_update: None,
            solver_scratch: solver::Scratch::default(),
        }
    }

    /// Install a rate solver (default: [`solver::FairShare`]). Rates are
    /// re-solved from the current instant.
    pub fn set_solver(&mut self, solver: Box<dyn solver::Solver>) {
        self.solver = solver;
        self.pending_update = None;
        self.dirty = true;
        self.epoch += 1;
    }

    /// Report label of the installed solver.
    pub fn solver_label(&self) -> &'static str {
        self.solver.label()
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn add_link(&mut self, name: &str, capacity: Gbps) -> LinkId {
        self.links.push(Link {
            name: name.to_string(),
            capacity_bps: capacity.bytes_per_sec(),
            rtt_s: 0.0,
            loss: 0.0,
            bytes_carried: 0.0,
            monitor: None,
        });
        LinkId(self.links.len() - 1)
    }

    /// Annotate a link with its RTT contribution and per-packet loss
    /// probability (consumed by dynamic solvers; ignored by fair-share).
    pub fn set_link_profile(&mut self, link: LinkId, rtt_s: f64, loss: f64) {
        self.links[link.0].rtt_s = rtt_s;
        self.links[link.0].loss = loss;
        self.dirty = true;
        self.epoch += 1;
    }

    /// Attach a throughput monitor with the given bin width.
    pub fn monitor_link(&mut self, link: LinkId, bin: SimTime) {
        self.links[link.0].monitor = Some(BinSeries::new(bin));
    }

    pub fn link(&self, id: LinkId) -> &Link {
        &self.links[id.0]
    }

    pub fn link_count(&self) -> usize {
        self.links.len()
    }

    /// Change a link's capacity (background-traffic modulation). Takes
    /// effect from the current instant; callers must have advanced time
    /// first.
    pub fn set_capacity(&mut self, link: LinkId, capacity: Gbps) {
        self.links[link.0].capacity_bps = capacity.bytes_per_sec();
        self.dirty = true;
        self.epoch += 1;
    }

    /// Start a flow of `bytes` along `path` with per-flow cap `cap_bps`.
    pub fn start_flow(&mut self, path: Vec<LinkId>, bytes: f64, cap_bps: f64) -> FlowId {
        debug_assert!(bytes > 0.0 && cap_bps > 0.0);
        debug_assert!(path.iter().all(|l| l.0 < self.links.len()));
        let id = FlowId(self.next_flow);
        self.next_flow += 1;
        self.flows.insert(
            id,
            Flow {
                path,
                remaining: bytes,
                total: bytes,
                cap_bps,
                rate: 0.0,
                started: self.now,
            },
        );
        self.dirty = true;
        self.epoch += 1;
        id
    }

    pub fn flow(&self, id: FlowId) -> Option<&Flow> {
        self.flows.get(&id)
    }

    pub fn active_flows(&self) -> usize {
        self.flows.len()
    }

    /// Re-run the rate solver if the flow set, capacities, or (for a
    /// dynamic solver) a scheduled window-update instant changed.
    pub fn resolve(&mut self) {
        if !self.dirty {
            return;
        }
        self.solver
            .solve(self.now, &self.links, &mut self.flows, &mut self.solver_scratch);
        self.pending_update = self.solver.next_update(self.now);
        self.dirty = false;
    }

    /// Advance virtual time to `t`, accruing bytes at current rates.
    ///
    /// Panics (debug) if any flow would finish strictly before `t`: the
    /// engine must advance to completion instants, harvest, then continue.
    pub fn advance_to(&mut self, t: SimTime) {
        self.resolve();
        let dt = t.since(self.now).as_secs_f64();
        if dt <= 0.0 {
            self.now = self.now.max(t);
            return;
        }
        // Per-link carried bytes = sum of flow rates crossing it.
        let mut link_bytes = vec![0.0f64; self.links.len()];
        for f in self.flows.values_mut() {
            let moved = (f.rate * dt).min(f.remaining);
            f.remaining -= moved;
            if f.remaining < 1e-6 {
                f.remaining = 0.0;
            }
            for l in &f.path {
                link_bytes[l.0] += moved;
            }
        }
        for (i, b) in link_bytes.iter().enumerate() {
            self.links[i].bytes_carried += b;
            if let Some(mon) = &mut self.links[i].monitor {
                mon.add_spread(self.now, t, *b);
            }
        }
        self.now = t;
        // Crossing a solver-scheduled update instant invalidates rates
        // (and any completion event computed from them).
        if self.pending_update.is_some_and(|u| u <= self.now) {
            self.pending_update = None;
            self.dirty = true;
            self.epoch += 1;
        }
    }

    /// Earliest instant at which the engine must act: some flow completes
    /// under current rates, or a dynamic solver wants a window update
    /// (None if no active flows or all rates are zero and no update is
    /// pending).
    pub fn next_completion(&mut self) -> Option<SimTime> {
        self.resolve();
        let mut best: Option<f64> = None;
        for f in self.flows.values() {
            if f.remaining <= 0.0 {
                return Some(self.now); // already done, harvest now
            }
            if f.rate > 0.0 {
                let eta = f.remaining / f.rate;
                best = Some(best.map_or(eta, |b: f64| b.min(eta)));
            }
        }
        // Round UP to the next nanosecond (+1) so that advancing to the
        // returned instant always consumes the full remaining bytes —
        // rounding down would leave sub-byte remainders and livelock the
        // event loop on zero-length advances.
        let completion = best.map(|eta| self.now + SimTime((eta * 1e9).ceil() as u64 + 1));
        let update = self.solver.next_update(self.now);
        match (completion, update) {
            (Some(c), Some(u)) => Some(c.min(u)),
            (c, u) => c.or(u),
        }
    }

    /// Flows that have finished by the current instant.
    pub fn completed(&self) -> Vec<FlowId> {
        let mut done: Vec<FlowId> = self
            .flows
            .iter()
            .filter(|(_, f)| f.remaining <= 0.0)
            .map(|(id, _)| *id)
            .collect();
        done.sort();
        done
    }

    /// Remove a completed (or cancelled) flow, returning its stats.
    pub fn finish_flow(&mut self, id: FlowId) -> Option<FlowStats> {
        let f = self.flows.remove(&id)?;
        self.dirty = true;
        self.epoch += 1;
        Some(FlowStats {
            bytes: f.total - f.remaining,
            started: f.started,
            finished: self.now,
        })
    }

    /// Current allocated rate of a flow in bytes/sec (after resolve).
    pub fn flow_rate(&mut self, id: FlowId) -> Option<f64> {
        self.resolve();
        self.flows.get(&id).map(|f| f.rate)
    }

    /// Aggregate rate crossing a link right now (after resolve).
    pub fn link_rate(&mut self, link: LinkId) -> f64 {
        self.resolve();
        self.flows
            .values()
            .filter(|f| f.path.contains(&link))
            .map(|f| f.rate)
            .sum()
    }

    /// Take the monitor series of a link (consumes it).
    pub fn take_monitor(&mut self, link: LinkId) -> Option<BinSeries> {
        self.links[link.0].monitor.take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gb(n: f64) -> f64 {
        n * 1e9
    }

    #[test]
    fn single_flow_bounded_by_link() {
        let mut net = NetSim::new();
        let l = net.add_link("nic", Gbps(8.0)); // 1 GB/s
        let f = net.start_flow(vec![l], gb(2.0), f64::INFINITY);
        assert!((net.flow_rate(f).unwrap() - 1e9).abs() < 1.0);
        let done = net.next_completion().unwrap();
        assert!((done.as_secs_f64() - 2.0).abs() < 1e-6);
        net.advance_to(done);
        assert_eq!(net.completed(), vec![f]);
        let st = net.finish_flow(f).unwrap();
        assert!((st.bytes - gb(2.0)).abs() < 1.0);
        assert!((st.mean_rate_bps() - 1e9).abs() < 1e3);
    }

    #[test]
    fn fair_share_two_flows() {
        let mut net = NetSim::new();
        let l = net.add_link("nic", Gbps(8.0));
        let f1 = net.start_flow(vec![l], gb(10.0), f64::INFINITY);
        let f2 = net.start_flow(vec![l], gb(10.0), f64::INFINITY);
        assert!((net.flow_rate(f1).unwrap() - 0.5e9).abs() < 1.0);
        assert!((net.flow_rate(f2).unwrap() - 0.5e9).abs() < 1.0);
    }

    #[test]
    fn per_flow_cap_respected_and_redistributed() {
        let mut net = NetSim::new();
        let l = net.add_link("nic", Gbps(8.0)); // 1 GB/s
        let capped = net.start_flow(vec![l], gb(10.0), 0.1e9);
        let free = net.start_flow(vec![l], gb(10.0), f64::INFINITY);
        assert!((net.flow_rate(capped).unwrap() - 0.1e9).abs() < 1.0);
        // The other flow picks up the slack (max-min, not plain 50/50).
        assert!((net.flow_rate(free).unwrap() - 0.9e9).abs() < 1.0);
    }

    #[test]
    fn multi_link_path_bounded_by_narrowest() {
        let mut net = NetSim::new();
        let wide = net.add_link("wide", Gbps(100.0));
        let narrow = net.add_link("narrow", Gbps(10.0));
        let f = net.start_flow(vec![wide, narrow], gb(5.0), f64::INFINITY);
        assert!((net.flow_rate(f).unwrap() - Gbps(10.0).bytes_per_sec()).abs() < 1.0);
    }

    #[test]
    fn flow_completion_ordering() {
        let mut net = NetSim::new();
        let l = net.add_link("nic", Gbps(8.0));
        let small = net.start_flow(vec![l], gb(1.0), f64::INFINITY);
        let big = net.start_flow(vec![l], gb(4.0), f64::INFINITY);
        // Both at 0.5 GB/s: small finishes at t=2.
        let t1 = net.next_completion().unwrap();
        assert!((t1.as_secs_f64() - 2.0).abs() < 1e-6);
        net.advance_to(t1);
        assert_eq!(net.completed(), vec![small]);
        net.finish_flow(small);
        // big now gets the full 1 GB/s with 3 GB left: finishes at t=5.
        let t2 = net.next_completion().unwrap();
        assert!((t2.as_secs_f64() - 5.0).abs() < 1e-6);
        net.advance_to(t2);
        assert_eq!(net.completed(), vec![big]);
    }

    #[test]
    fn capacity_change_rebalances() {
        let mut net = NetSim::new();
        let l = net.add_link("backbone", Gbps(10.0));
        let f = net.start_flow(vec![l], gb(100.0), f64::INFINITY);
        net.advance_to(SimTime::from_secs(1));
        net.set_capacity(l, Gbps(2.0));
        let r = net.flow_rate(f).unwrap();
        assert!((r - Gbps(2.0).bytes_per_sec()).abs() < 1.0);
    }

    #[test]
    fn link_accounting_and_monitor() {
        let mut net = NetSim::new();
        let l = net.add_link("nic", Gbps(8.0));
        net.monitor_link(l, SimTime::from_secs(1));
        net.start_flow(vec![l], gb(3.0), f64::INFINITY);
        net.advance_to(SimTime::from_secs(3));
        assert!((net.link(l).bytes_carried - gb(3.0)).abs() < 1.0);
        let mon = net.take_monitor(l).unwrap();
        let bins = mon.bins();
        assert_eq!(bins.len(), 3);
        for (_, b) in bins {
            assert!((b - gb(1.0)).abs() < 1e3, "each 1s bin carries 1GB, got {b}");
        }
    }

    #[test]
    fn epoch_bumps_on_changes() {
        let mut net = NetSim::new();
        let l = net.add_link("nic", Gbps(1.0));
        let e0 = net.epoch;
        let f = net.start_flow(vec![l], 100.0, 1e9);
        assert!(net.epoch > e0);
        let e1 = net.epoch;
        net.finish_flow(f);
        assert!(net.epoch > e1);
    }

    #[test]
    fn zero_active_flows() {
        let mut net = NetSim::new();
        net.add_link("nic", Gbps(1.0));
        assert!(net.next_completion().is_none());
        assert!(net.completed().is_empty());
        net.advance_to(SimTime::from_secs(10));
        assert_eq!(net.now(), SimTime::from_secs(10));
    }
}
