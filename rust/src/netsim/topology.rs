//! Testbed topology builders: the PRP deployments from the paper's §II–§IV
//! expressed as NetSim link graphs.
//!
//! A transfer from submit node `s` to worker `w` crosses, in order:
//!
//! ```text
//!   [submit s VPN cpu]? -> submit s NIC tx -> [backbone]? -> worker w NIC rx
//! ```
//!
//! The paper's deployments have one submit node; `n_submit_nodes > 1`
//! models the scale-out pool (one NIC + monitor per submit node, each
//! fed by its own `ShadowPool` behind the `PoolRouter`).
//!
//! * LAN scenario (§III): submit + 6 workers, all 100 Gbps NICs, no
//!   backbone constraint beyond the (quiet) campus core.
//! * WAN scenario (§IV): workers in New York (1×100 Gbps + 4×10 Gbps),
//!   shared 100 Gbps cross-US backbone with background traffic, 58 ms RTT.
//! * VPN ablation (§II): the submit pod runs behind the Calico overlay —
//!   an extra per-node processing resource capping encap throughput.

use super::calib;
use super::tcp::PathProfile;
use super::{LinkId, NetSim};
use crate::util::site_of_member;
use crate::util::units::{Gbps, SimTime};

/// One worker node: NIC capacity and number of execute slots.
#[derive(Debug, Clone, Copy)]
pub struct WorkerSpec {
    pub nic_gbps: f64,
    pub slots: u32,
}

/// WAN path characteristics (None = LAN-only deployment).
#[derive(Debug, Clone, Copy)]
pub struct WanSpec {
    pub rtt_s: f64,
    pub loss: f64,
    pub backbone_gbps: f64,
}

/// Full testbed specification.
#[derive(Debug, Clone)]
pub struct TestbedSpec {
    pub submit_nic_gbps: f64,
    /// Submit-node count; each node gets its own NIC (and VPN hop, when
    /// enabled). 1 = the paper's deployments.
    pub n_submit_nodes: u32,
    /// Per-submit-node NIC overrides in Gbps (heterogeneous fleets).
    /// Empty = every node gets `submit_nic_gbps`; extra entries beyond
    /// `n_submit_nodes` are ignored, missing ones fall back.
    pub submit_node_gbps: Vec<f64>,
    /// Dedicated data-transfer-node count (0 = the paper's deployments:
    /// every byte through the submit funnel). Each data node gets its
    /// own monitored NIC, outside the VPN overlay.
    pub n_data_nodes: u32,
    /// Default data-node NIC capacity in Gbps.
    pub data_nic_gbps: f64,
    /// Per-data-node NIC overrides in Gbps (same fallback semantics as
    /// `submit_node_gbps`).
    pub data_node_gbps: Vec<f64>,
    /// Page-cache capacity of each data node in bytes (the engine's
    /// storage model behind cache-aware source selection: warm extents
    /// stream at page-cache rate, cold ones at the device's).
    pub dtn_cache_bytes: u64,
    /// Model each data node's bulk store as a spinning device
    /// (seek-bound under concurrent readers) instead of NVMe flash —
    /// the archive-grade GridFTP/DTN deployments the Petascale DTN
    /// project benchmarked.
    pub dtn_spinning: bool,
    pub workers: Vec<WorkerSpec>,
    pub wan: Option<WanSpec>,
    /// Submit node runs behind the Calico VPN overlay (unprivileged pod).
    pub vpn_on_submit: bool,
    /// Width of the throughput monitor bins on the submit NIC.
    pub monitor_bin: SimTime,
    /// Override the path round trip in milliseconds (`LINK_RTT_MS` knob).
    /// Takes precedence over the WAN spec's RTT; on LAN-only topologies
    /// it annotates the sender NIC hop. `None` = the calibrated default.
    pub link_rtt_ms: Option<f64>,
    /// Override the per-packet path loss probability (`LINK_LOSS` knob);
    /// same precedence as `link_rtt_ms`.
    pub link_loss: Option<f64>,
    /// Override the per-stream endpoint ceiling in bytes/sec (the
    /// calibration harness pins this to a measured loopback rate).
    pub endpoint_bps: Option<f64>,
    /// Federation site count (`N_SITES` knob). 1 = the paper's single
    /// deployment. With more, the submit fleet, data fleet and workers
    /// partition into contiguous per-site blocks
    /// ([`crate::util::site_of_member`]), each site gets a monitored
    /// border link, and every site pair gets a WAN link — a transfer
    /// whose source and worker live on different sites crosses
    /// src-border → pair WAN → dst-border.
    pub n_sites: u32,
    /// Border-link capacity of every site in Gbps (`SITE_WAN_GBPS`),
    /// the Petascale DTN per-site provisioning target.
    pub site_wan_gbps: f64,
    /// Round trip between any two sites in milliseconds
    /// (`SITE_WAN_RTT_MS`), stamped on the pair WAN links.
    pub site_wan_rtt_ms: f64,
    /// Per-packet loss probability on the pair WAN links.
    pub site_wan_loss: f64,
}

impl TestbedSpec {
    /// §III LAN test: 6 workers × 100 Gbps NIC, 200 slots total.
    pub fn lan_paper() -> TestbedSpec {
        TestbedSpec {
            submit_nic_gbps: 100.0,
            n_submit_nodes: 1,
            submit_node_gbps: Vec::new(),
            n_data_nodes: 0,
            data_nic_gbps: 100.0,
            data_node_gbps: Vec::new(),
            dtn_cache_bytes: 8 << 30,
            dtn_spinning: false,
            workers: (0..6)
                .map(|i| WorkerSpec {
                    nic_gbps: 100.0,
                    // 200 slots over 6 nodes: 34,34,33,33,33,33
                    slots: if i < 2 { 34 } else { 33 },
                })
                .collect(),
            wan: None,
            vpn_on_submit: false,
            monitor_bin: SimTime::from_secs(60),
            link_rtt_ms: None,
            link_loss: None,
            endpoint_bps: None,
            n_sites: 1,
            site_wan_gbps: 100.0,
            site_wan_rtt_ms: calib::WAN_RTT_S * 1000.0,
            site_wan_loss: calib::WAN_LOSS,
        }
    }

    /// §IV WAN test: NY workers, 1×100 Gbps + 4×10 Gbps, 58 ms RTT.
    pub fn wan_paper() -> TestbedSpec {
        let mut workers = vec![WorkerSpec {
            nic_gbps: 100.0,
            slots: 120,
        }];
        workers.extend((0..4).map(|_| WorkerSpec {
            nic_gbps: 10.0,
            slots: 20,
        }));
        TestbedSpec {
            submit_nic_gbps: 100.0,
            n_submit_nodes: 1,
            submit_node_gbps: Vec::new(),
            n_data_nodes: 0,
            data_nic_gbps: 100.0,
            data_node_gbps: Vec::new(),
            dtn_cache_bytes: 8 << 30,
            dtn_spinning: false,
            workers,
            wan: Some(WanSpec {
                rtt_s: calib::WAN_RTT_S,
                loss: calib::WAN_LOSS,
                backbone_gbps: 100.0,
            }),
            vpn_on_submit: false,
            monitor_bin: SimTime::from_secs(60),
            link_rtt_ms: None,
            link_loss: None,
            endpoint_bps: None,
            n_sites: 1,
            site_wan_gbps: 100.0,
            site_wan_rtt_ms: calib::WAN_RTT_S * 1000.0,
            site_wan_loss: calib::WAN_LOSS,
        }
    }

    /// §II VPN ablation: LAN deployment, submit pod behind Calico.
    pub fn lan_vpn_paper() -> TestbedSpec {
        TestbedSpec {
            vpn_on_submit: true,
            ..TestbedSpec::lan_paper()
        }
    }

    pub fn total_slots(&self) -> u32 {
        self.workers.iter().map(|w| w.slots).sum()
    }

    /// NIC capacity of submit node `s` in Gbps (override or default).
    pub fn submit_node_nic_gbps(&self, s: usize) -> f64 {
        self.submit_node_gbps
            .get(s)
            .copied()
            .unwrap_or(self.submit_nic_gbps)
    }

    /// NIC capacity of data node `d` in Gbps (override or default).
    pub fn data_node_nic_gbps(&self, d: usize) -> f64 {
        self.data_node_gbps
            .get(d)
            .copied()
            .unwrap_or(self.data_nic_gbps)
    }
}

/// A built testbed: the NetSim plus the link handles the engine needs.
#[derive(Debug)]
pub struct Testbed {
    pub net: NetSim,
    pub spec: TestbedSpec,
    /// One monitored tx link per submit node (index = node).
    pub submit_txs: Vec<LinkId>,
    /// One VPN processing hop per submit node when the overlay is on;
    /// empty otherwise.
    pub submit_vpns: Vec<LinkId>,
    /// One monitored tx link per dedicated data node (index = dtn).
    /// Data nodes sit outside the VPN overlay — they are dedicated data
    /// movers, which is exactly why DTN deployments escape the paper's
    /// ~25 Gbps overlay ceiling.
    pub data_txs: Vec<LinkId>,
    pub backbone: Option<LinkId>,
    pub worker_rx: Vec<LinkId>,
    /// One monitored border link per federation site (empty with
    /// `n_sites <= 1`). Every byte leaving or entering a site crosses
    /// its border; [`Testbed::set_site_border_gbps`] drains it on
    /// `fail_site`.
    pub site_borders: Vec<LinkId>,
    /// One WAN link per unordered site pair, in triangular order
    /// (0-1, 0-2, …, 1-2, …); [`Testbed::site_pair_link`] indexes it.
    pub site_pairs: Vec<LinkId>,
}

impl Testbed {
    pub fn build(spec: TestbedSpec) -> Testbed {
        let mut net = NetSim::new();
        let eff = calib::NIC_PROTOCOL_EFFICIENCY;
        let n_submit = spec.n_submit_nodes.max(1) as usize;

        let mut submit_vpns = Vec::new();
        let mut submit_txs = Vec::with_capacity(n_submit);
        for s in 0..n_submit {
            if spec.vpn_on_submit {
                submit_vpns.push(
                    net.add_link(&format!("submit{s}.vpn"), Gbps(calib::VPN_PROCESSING_GBPS)),
                );
            }
            let tx = net.add_link(
                &format!("submit{s}.nic.tx"),
                Gbps(spec.submit_node_nic_gbps(s) * eff),
            );
            net.monitor_link(tx, spec.monitor_bin);
            submit_txs.push(tx);
        }

        let mut data_txs = Vec::with_capacity(spec.n_data_nodes as usize);
        for d in 0..spec.n_data_nodes as usize {
            let tx = net.add_link(
                &format!("data{d}.nic.tx"),
                Gbps(spec.data_node_nic_gbps(d) * eff),
            );
            net.monitor_link(tx, spec.monitor_bin);
            data_txs.push(tx);
        }

        let backbone = spec
            .wan
            .map(|w| net.add_link("backbone", Gbps(w.backbone_gbps * eff)));

        let worker_rx = spec
            .workers
            .iter()
            .enumerate()
            .map(|(i, w)| net.add_link(&format!("worker{i}.nic.rx"), Gbps(w.nic_gbps * eff)))
            .collect();

        // Federation fabric: per-site border links plus a WAN link per
        // site pair. RTT/loss live on the pair links only, so a
        // cross-site path pays them exactly once.
        let n_sites = spec.n_sites.max(1) as usize;
        let mut site_borders = Vec::new();
        let mut site_pairs = Vec::new();
        if n_sites > 1 {
            for s in 0..n_sites {
                let border =
                    net.add_link(&format!("site{s}.border"), Gbps(spec.site_wan_gbps * eff));
                net.monitor_link(border, spec.monitor_bin);
                site_borders.push(border);
            }
            for a in 0..n_sites {
                for b in (a + 1)..n_sites {
                    let wan =
                        net.add_link(&format!("wan.s{a}-s{b}"), Gbps(spec.site_wan_gbps * eff));
                    net.set_link_profile(wan, spec.site_wan_rtt_ms / 1000.0, spec.site_wan_loss);
                    site_pairs.push(wan);
                }
            }
        }

        // RTT/loss annotations for dynamic solvers. The WAN's latency and
        // loss live on the backbone hop; explicit `link_rtt_ms`/`link_loss`
        // overrides take precedence and, on LAN-only topologies, land on
        // the sender NIC hops (once per path — worker rx stays clean so a
        // path never double-counts).
        let rtt_s = spec
            .link_rtt_ms
            .map(|ms| ms / 1000.0)
            .or(spec.wan.map(|w| w.rtt_s));
        let loss = spec.link_loss.or(spec.wan.map(|w| w.loss));
        if rtt_s.is_some() || loss.is_some() {
            let (r, l) = (rtt_s.unwrap_or(0.0), loss.unwrap_or(0.0));
            if let Some(b) = backbone {
                net.set_link_profile(b, r, l);
            } else {
                for &tx in submit_txs.iter().chain(data_txs.iter()) {
                    net.set_link_profile(tx, r, l);
                }
            }
        }

        Testbed {
            net,
            spec,
            submit_txs,
            submit_vpns,
            data_txs,
            backbone,
            worker_rx,
            site_borders,
            site_pairs,
        }
    }

    /// Submit-node count this testbed was built with.
    pub fn n_submit_nodes(&self) -> usize {
        self.submit_txs.len()
    }

    /// Dedicated data-node count this testbed was built with.
    pub fn n_data_nodes(&self) -> usize {
        self.data_txs.len()
    }

    /// Federation site count (1 = no federation fabric built).
    pub fn n_sites(&self) -> usize {
        self.site_borders.len().max(1)
    }

    /// Site of submit node `s` (canonical contiguous partition).
    pub fn site_of_submit(&self, s: usize) -> usize {
        site_of_member(s, self.submit_txs.len(), self.n_sites())
    }

    /// Site of data node `d`.
    pub fn site_of_dtn(&self, d: usize) -> usize {
        site_of_member(d, self.data_txs.len(), self.n_sites())
    }

    /// Site of worker `w`.
    pub fn site_of_worker(&self, w: usize) -> usize {
        site_of_member(w, self.worker_rx.len(), self.n_sites())
    }

    /// The WAN link between two distinct sites (triangular pair index);
    /// `None` for a same-site pair or a federation-less testbed.
    pub fn site_pair_link(&self, a: usize, b: usize) -> Option<LinkId> {
        if a == b || self.site_borders.is_empty() {
            return None;
        }
        let n = self.n_sites();
        let (lo, hi) = (a.min(b), a.max(b));
        let idx = lo * n - lo * (lo + 1) / 2 + (hi - lo - 1);
        self.site_pairs.get(idx).copied()
    }

    /// Append the cross-site hops (src border → pair WAN → dst border)
    /// when a path leaves its source's site; a no-op otherwise.
    fn push_wan_hops(&self, p: &mut Vec<LinkId>, src_site: usize, dst_site: usize) {
        if src_site == dst_site || self.site_borders.is_empty() {
            return;
        }
        p.push(self.site_borders[src_site]);
        if let Some(wan) = self.site_pair_link(src_site, dst_site) {
            p.push(wan);
        }
        p.push(self.site_borders[dst_site]);
    }

    /// Re-rate one site's border link mid-run (`fail_site` drains it to
    /// the positive-capacity floor, `recover_site` restores the spec
    /// rate); same derating as every other NIC.
    pub fn set_site_border_gbps(&mut self, site: usize, gbps: f64) {
        let eff = calib::NIC_PROTOCOL_EFFICIENCY;
        let link = self.site_borders[site];
        self.net.set_capacity(link, Gbps(gbps.max(0.001) * eff));
    }

    /// Re-rate one submit node's NIC mid-run (fault injection: degrade,
    /// or restore on recovery). `gbps` is nominal; protocol-efficiency
    /// derating applies exactly as in [`Testbed::build`]. A floor keeps
    /// the link's capacity strictly positive so flows never stall
    /// forever on a zero-rate link.
    pub fn set_submit_nic_gbps(&mut self, node: usize, gbps: f64) {
        let eff = calib::NIC_PROTOCOL_EFFICIENCY;
        let link = self.submit_txs[node];
        self.net.set_capacity(link, Gbps(gbps.max(0.001) * eff));
    }

    /// Links crossed by a submit node -> worker transfer. When the node
    /// and worker live on different federation sites, the path also
    /// crosses both borders and the pair WAN link.
    pub fn path_to_worker(&self, submit_node: usize, worker: usize) -> Vec<LinkId> {
        let mut p = Vec::with_capacity(7);
        if let Some(&v) = self.submit_vpns.get(submit_node) {
            p.push(v);
        }
        p.push(self.submit_txs[submit_node]);
        self.push_wan_hops(
            &mut p,
            self.site_of_submit(submit_node),
            self.site_of_worker(worker),
        );
        if let Some(b) = self.backbone {
            p.push(b);
        }
        p.push(self.worker_rx[worker]);
        p
    }

    /// Links crossed by a worker -> submit node transfer (job output).
    /// The same resources are crossed in the reverse direction; NIC
    /// duplex is approximated as shared capacity, which is conservative
    /// and matches the submit node being the hot spot.
    pub fn path_from_worker(&self, submit_node: usize, worker: usize) -> Vec<LinkId> {
        let mut p = self.path_to_worker(submit_node, worker);
        p.reverse();
        p
    }

    /// Links crossed by a data node -> worker transfer. Data nodes sit
    /// outside the VPN overlay (no encap hop); cross-site transfers pay
    /// the same border/WAN hops as the funnel path.
    pub fn dtn_path_to_worker(&self, dtn: usize, worker: usize) -> Vec<LinkId> {
        let mut p = Vec::with_capacity(6);
        p.push(self.data_txs[dtn]);
        self.push_wan_hops(&mut p, self.site_of_dtn(dtn), self.site_of_worker(worker));
        if let Some(b) = self.backbone {
            p.push(b);
        }
        p.push(self.worker_rx[worker]);
        p
    }

    /// Links crossed by a worker -> data node transfer (job output via
    /// the data plane); same duplex approximation as
    /// [`Testbed::path_from_worker`].
    pub fn dtn_path_from_worker(&self, dtn: usize, worker: usize) -> Vec<LinkId> {
        let mut p = self.dtn_path_to_worker(dtn, worker);
        p.reverse();
        p
    }

    /// Re-rate one data node's NIC mid-run (fault injection), with the
    /// same derating and positive-capacity floor as
    /// [`Testbed::set_submit_nic_gbps`].
    pub fn set_data_nic_gbps(&mut self, dtn: usize, gbps: f64) {
        let eff = calib::NIC_PROTOCOL_EFFICIENCY;
        let link = self.data_txs[dtn];
        self.net.set_capacity(link, Gbps(gbps.max(0.001) * eff));
    }

    /// TCP path profile for transfers to any worker in this testbed,
    /// with the spec's `link_rtt_ms`/`link_loss`/`endpoint_bps`
    /// overrides applied.
    pub fn path_profile(&self) -> PathProfile {
        let mut p = match self.spec.wan {
            None => PathProfile::lan(),
            Some(w) => PathProfile {
                rtt_s: w.rtt_s,
                loss: w.loss,
                window_bytes: calib::TCP_WINDOW_BYTES,
                endpoint_bps: calib::PER_STREAM_ENDPOINT_BPS,
            },
        };
        if let Some(ms) = self.spec.link_rtt_ms {
            p.rtt_s = ms / 1000.0;
        }
        if let Some(l) = self.spec.link_loss {
            p.loss = l;
        }
        if let Some(e) = self.spec.endpoint_bps {
            p.endpoint_bps = e;
        }
        p
    }

    /// [`Testbed::path_profile`] for a transfer between two sites: a
    /// cross-site path additionally pays the federation WAN's RTT and
    /// compounds its loss. Same-site transfers see the base profile.
    pub fn site_path_profile(&self, src_site: usize, dst_site: usize) -> PathProfile {
        let mut p = self.path_profile();
        if src_site != dst_site && !self.site_borders.is_empty() {
            p.rtt_s += self.spec.site_wan_rtt_ms / 1000.0;
            p.loss = 1.0 - (1.0 - p.loss) * (1.0 - self.spec.site_wan_loss);
        }
        p
    }

    /// Background-traffic parameters for the shared path, if any:
    /// (link, mean utilization, sd, step seconds, nominal Gbps).
    pub fn background(&self) -> Option<(LinkId, f64, f64, f64, f64)> {
        let eff = calib::NIC_PROTOCOL_EFFICIENCY;
        match (self.backbone, self.spec.wan) {
            (Some(b), Some(w)) => Some((
                b,
                calib::WAN_BG_MEAN,
                calib::WAN_BG_SD,
                calib::WAN_BG_STEP_S,
                w.backbone_gbps * eff,
            )),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lan_paper_shape() {
        let spec = TestbedSpec::lan_paper();
        assert_eq!(spec.workers.len(), 6);
        assert_eq!(spec.total_slots(), 200);
        let tb = Testbed::build(spec);
        assert!(tb.backbone.is_none());
        assert!(tb.submit_vpns.is_empty());
        assert_eq!(tb.n_submit_nodes(), 1);
        assert_eq!(tb.worker_rx.len(), 6);
        let p = tb.path_to_worker(0, 3);
        assert_eq!(p, vec![tb.submit_txs[0], tb.worker_rx[3]]);
    }

    #[test]
    fn multi_submit_nodes_get_own_monitored_nics() {
        let mut spec = TestbedSpec::lan_paper();
        spec.n_submit_nodes = 4;
        let tb = Testbed::build(spec);
        assert_eq!(tb.n_submit_nodes(), 4);
        assert_eq!(tb.submit_txs.len(), 4);
        // Distinct NICs: paths from different submit nodes share only the
        // worker rx link.
        let p0 = tb.path_to_worker(0, 2);
        let p3 = tb.path_to_worker(3, 2);
        assert_ne!(p0[0], p3[0]);
        assert_eq!(p0[1], p3[1]);
        // Each submit NIC carries the full per-node capacity.
        for &tx in &tb.submit_txs {
            let cap = tb.net.link(tx).capacity_bps * 8.0 / 1e9;
            assert!((cap - 91.0).abs() < 0.01);
        }
    }

    #[test]
    fn heterogeneous_submit_nics_get_per_node_capacity() {
        let mut spec = TestbedSpec::lan_paper();
        spec.n_submit_nodes = 2;
        spec.submit_node_gbps = vec![100.0, 25.0];
        assert_eq!(spec.submit_node_nic_gbps(0), 100.0);
        assert_eq!(spec.submit_node_nic_gbps(1), 25.0);
        assert_eq!(spec.submit_node_nic_gbps(9), 100.0, "fallback to default");
        let tb = Testbed::build(spec);
        let c0 = tb.net.link(tb.submit_txs[0]).capacity_bps * 8.0 / 1e9;
        let c1 = tb.net.link(tb.submit_txs[1]).capacity_bps * 8.0 / 1e9;
        assert!((c0 - 91.0).abs() < 0.01);
        assert!((c1 - 22.75).abs() < 0.01, "25 Gbps derated: {c1}");
    }

    #[test]
    fn data_nodes_get_own_monitored_nics_outside_the_overlay() {
        let mut spec = TestbedSpec::lan_vpn_paper();
        spec.n_data_nodes = 2;
        spec.data_node_gbps = vec![100.0, 25.0];
        assert_eq!(spec.data_node_nic_gbps(1), 25.0);
        assert_eq!(spec.data_node_nic_gbps(5), 100.0, "fallback to default");
        let tb = Testbed::build(spec);
        assert_eq!(tb.n_data_nodes(), 2);
        // DTN paths skip the VPN hop the submit funnel pays.
        let funnel = tb.path_to_worker(0, 1);
        assert_eq!(funnel.len(), 3, "vpn + submit tx + worker rx");
        let dtn = tb.dtn_path_to_worker(0, 1);
        assert_eq!(dtn, vec![tb.data_txs[0], tb.worker_rx[1]]);
        // Reverse path crosses the same links.
        let mut rev = tb.dtn_path_from_worker(0, 1);
        rev.reverse();
        assert_eq!(rev, dtn);
        // Per-DTN capacities are derated like every other NIC.
        let c1 = tb.net.link(tb.data_txs[1]).capacity_bps * 8.0 / 1e9;
        assert!((c1 - 22.75).abs() < 0.01, "25 Gbps derated: {c1}");
    }

    #[test]
    fn data_nic_rerates_with_efficiency() {
        let mut spec = TestbedSpec::lan_paper();
        spec.n_data_nodes = 1;
        let mut tb = Testbed::build(spec);
        tb.set_data_nic_gbps(0, 25.0);
        let cap = tb.net.link(tb.data_txs[0]).capacity_bps * 8.0 / 1e9;
        assert!((cap - 22.75).abs() < 0.01, "degraded: {cap}");
        tb.set_data_nic_gbps(0, 100.0);
        let cap = tb.net.link(tb.data_txs[0]).capacity_bps * 8.0 / 1e9;
        assert!((cap - 91.0).abs() < 0.01, "restored: {cap}");
    }

    #[test]
    fn wan_paper_shape() {
        let spec = TestbedSpec::wan_paper();
        assert_eq!(spec.total_slots(), 200);
        assert_eq!(spec.workers[0].nic_gbps, 100.0);
        assert_eq!(spec.workers[4].nic_gbps, 10.0);
        let tb = Testbed::build(spec);
        let p = tb.path_to_worker(0, 0);
        assert_eq!(p.len(), 3, "submit tx + backbone + worker rx");
        assert!((tb.path_profile().rtt_s - 0.058).abs() < 1e-9);
    }

    #[test]
    fn vpn_adds_processing_hop() {
        let tb = Testbed::build(TestbedSpec::lan_vpn_paper());
        let p = tb.path_to_worker(0, 0);
        assert_eq!(p.len(), 3, "vpn + submit tx + worker rx");
        let vpn = tb.submit_vpns[0];
        assert_eq!(p[0], vpn);
        // VPN capacity is the paper's observed 25 Gbps ceiling.
        let cap = tb.net.link(vpn).capacity_bps * 8.0 / 1e9;
        assert!((cap - 25.0).abs() < 1e-9);
    }

    #[test]
    fn submit_nic_rerates_with_efficiency() {
        let mut tb = Testbed::build(TestbedSpec::lan_paper());
        tb.set_submit_nic_gbps(0, 25.0);
        let cap = tb.net.link(tb.submit_txs[0]).capacity_bps * 8.0 / 1e9;
        assert!((cap - 22.75).abs() < 0.01, "degraded: {cap}");
        tb.set_submit_nic_gbps(0, 100.0);
        let cap = tb.net.link(tb.submit_txs[0]).capacity_bps * 8.0 / 1e9;
        assert!((cap - 91.0).abs() < 0.01, "restored: {cap}");
    }

    #[test]
    fn nic_derated_by_protocol_efficiency() {
        let tb = Testbed::build(TestbedSpec::lan_paper());
        let cap_gbps = tb.net.link(tb.submit_txs[0]).capacity_bps * 8.0 / 1e9;
        assert!((cap_gbps - 91.0).abs() < 0.01);
    }

    #[test]
    fn reverse_path() {
        let tb = Testbed::build(TestbedSpec::wan_paper());
        let fwd = tb.path_to_worker(0, 1);
        let mut rev = tb.path_from_worker(0, 1);
        rev.reverse();
        assert_eq!(fwd, rev);
    }

    #[test]
    fn wan_rtt_and_loss_stamped_on_backbone() {
        let tb = Testbed::build(TestbedSpec::wan_paper());
        let b = tb.net.link(tb.backbone.unwrap());
        assert!((b.rtt_s - calib::WAN_RTT_S).abs() < 1e-12);
        assert!((b.loss - calib::WAN_LOSS).abs() < 1e-15);
        // LAN links stay unannotated.
        assert_eq!(tb.net.link(tb.submit_txs[0]).rtt_s, 0.0);
    }

    #[test]
    fn link_overrides_beat_wan_defaults_and_reach_lan_nics() {
        let mut spec = TestbedSpec::wan_paper();
        spec.link_rtt_ms = Some(200.0);
        spec.link_loss = Some(1e-5);
        let tb = Testbed::build(spec);
        let b = tb.net.link(tb.backbone.unwrap());
        assert!((b.rtt_s - 0.2).abs() < 1e-12);
        assert!((b.loss - 1e-5).abs() < 1e-15);
        assert!((tb.path_profile().rtt_s - 0.2).abs() < 1e-12);

        let mut spec = TestbedSpec::lan_paper();
        spec.n_data_nodes = 1;
        spec.link_rtt_ms = Some(50.0);
        let tb = Testbed::build(spec);
        assert!((tb.net.link(tb.submit_txs[0]).rtt_s - 0.05).abs() < 1e-12);
        assert!((tb.net.link(tb.data_txs[0]).rtt_s - 0.05).abs() < 1e-12);
        assert_eq!(tb.net.link(tb.worker_rx[0]).rtt_s, 0.0, "once per path");
    }

    #[test]
    fn endpoint_override_reaches_path_profile() {
        let mut spec = TestbedSpec::lan_paper();
        spec.endpoint_bps = Some(42e6);
        let tb = Testbed::build(spec);
        assert!((tb.path_profile().endpoint_bps - 42e6).abs() < 1.0);
    }

    #[test]
    fn single_site_builds_no_federation_fabric() {
        let tb = Testbed::build(TestbedSpec::lan_paper());
        assert_eq!(tb.n_sites(), 1);
        assert!(tb.site_borders.is_empty() && tb.site_pairs.is_empty());
        assert_eq!(tb.site_of_worker(5), 0);
        assert!(tb.site_pair_link(0, 0).is_none());
    }

    #[test]
    fn federation_builds_borders_and_pair_wans() {
        let mut spec = TestbedSpec::lan_paper();
        spec.n_sites = 3;
        spec.site_wan_gbps = 50.0;
        spec.site_wan_rtt_ms = 40.0;
        spec.site_wan_loss = 1e-6;
        let tb = Testbed::build(spec);
        assert_eq!(tb.n_sites(), 3);
        assert_eq!(tb.site_borders.len(), 3);
        assert_eq!(tb.site_pairs.len(), 3, "3 choose 2 pair links");
        // Triangular pair index: (0,1) (0,2) (1,2), symmetric lookup.
        assert_eq!(tb.site_pair_link(0, 1), Some(tb.site_pairs[0]));
        assert_eq!(tb.site_pair_link(2, 0), Some(tb.site_pairs[1]));
        assert_eq!(tb.site_pair_link(1, 2), Some(tb.site_pairs[2]));
        // RTT/loss live on the pair links only; borders carry capacity.
        let wan = tb.net.link(tb.site_pairs[0]);
        assert!((wan.rtt_s - 0.04).abs() < 1e-12);
        assert!((wan.loss - 1e-6).abs() < 1e-15);
        assert_eq!(tb.net.link(tb.site_borders[0]).rtt_s, 0.0);
        let cap = tb.net.link(tb.site_borders[0]).capacity_bps * 8.0 / 1e9;
        assert!((cap - 45.5).abs() < 0.01, "50 Gbps derated: {cap}");
        // The 6 workers partition 2 per site.
        assert_eq!(tb.site_of_worker(0), 0);
        assert_eq!(tb.site_of_worker(3), 1);
        assert_eq!(tb.site_of_worker(5), 2);
    }

    #[test]
    fn cross_site_paths_cross_borders_and_the_wan() {
        let mut spec = TestbedSpec::lan_paper();
        spec.n_sites = 2;
        spec.n_submit_nodes = 2;
        spec.n_data_nodes = 2;
        let tb = Testbed::build(spec);
        assert_eq!(tb.site_of_submit(0), 0);
        assert_eq!(tb.site_of_submit(1), 1);
        assert_eq!(tb.site_of_dtn(1), 1);
        // Same-site path: untouched shape.
        assert_eq!(
            tb.path_to_worker(0, 0),
            vec![tb.submit_txs[0], tb.worker_rx[0]]
        );
        // Cross-site: tx → src border → pair WAN → dst border → rx.
        assert_eq!(
            tb.path_to_worker(0, 4),
            vec![
                tb.submit_txs[0],
                tb.site_borders[0],
                tb.site_pairs[0],
                tb.site_borders[1],
                tb.worker_rx[4]
            ]
        );
        assert_eq!(
            tb.dtn_path_to_worker(1, 0),
            vec![
                tb.data_txs[1],
                tb.site_borders[1],
                tb.site_pairs[0],
                tb.site_borders[0],
                tb.worker_rx[0]
            ]
        );
        // Reverse path is the same links reversed.
        let mut rev = tb.path_from_worker(0, 4);
        rev.reverse();
        assert_eq!(rev, tb.path_to_worker(0, 4));
        // Cross-site TCP profile pays the federation RTT; local doesn't.
        let base = tb.path_profile().rtt_s;
        assert!((tb.site_path_profile(0, 0).rtt_s - base).abs() < 1e-12);
        let cross = tb.site_path_profile(0, 1).rtt_s;
        assert!((cross - (base + tb.spec.site_wan_rtt_ms / 1000.0)).abs() < 1e-9);
    }

    #[test]
    fn site_border_rerates_with_floor() {
        let mut spec = TestbedSpec::lan_paper();
        spec.n_sites = 2;
        let mut tb = Testbed::build(spec);
        tb.set_site_border_gbps(0, 0.0);
        let cap = tb.net.link(tb.site_borders[0]).capacity_bps * 8.0 / 1e9;
        assert!(cap > 0.0 && cap < 0.001, "drained to the floor: {cap}");
        tb.set_site_border_gbps(0, 100.0);
        let cap = tb.net.link(tb.site_borders[0]).capacity_bps * 8.0 / 1e9;
        assert!((cap - 91.0).abs() < 0.01, "restored: {cap}");
    }

    #[test]
    fn background_only_on_wan() {
        let lan = Testbed::build(TestbedSpec::lan_paper());
        assert!(lan.background().is_none());
        let wan = Testbed::build(TestbedSpec::wan_paper());
        let (link, mean, _, _, nominal) = wan.background().unwrap();
        assert_eq!(link, wan.backbone.unwrap());
        assert!(mean > 0.0 && nominal > 90.0);
    }
}
