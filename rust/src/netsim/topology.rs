//! Testbed topology builders: the PRP deployments from the paper's §II–§IV
//! expressed as NetSim link graphs.
//!
//! A transfer from the submit node to worker `w` crosses, in order:
//!
//! ```text
//!   [submit VPN cpu]? -> submit NIC tx -> [backbone]? -> worker w NIC rx
//! ```
//!
//! * LAN scenario (§III): submit + 6 workers, all 100 Gbps NICs, no
//!   backbone constraint beyond the (quiet) campus core.
//! * WAN scenario (§IV): workers in New York (1×100 Gbps + 4×10 Gbps),
//!   shared 100 Gbps cross-US backbone with background traffic, 58 ms RTT.
//! * VPN ablation (§II): the submit pod runs behind the Calico overlay —
//!   an extra per-node processing resource capping encap throughput.

use super::calib;
use super::tcp::PathProfile;
use super::{LinkId, NetSim};
use crate::util::units::{Gbps, SimTime};

/// One worker node: NIC capacity and number of execute slots.
#[derive(Debug, Clone, Copy)]
pub struct WorkerSpec {
    pub nic_gbps: f64,
    pub slots: u32,
}

/// WAN path characteristics (None = LAN-only deployment).
#[derive(Debug, Clone, Copy)]
pub struct WanSpec {
    pub rtt_s: f64,
    pub loss: f64,
    pub backbone_gbps: f64,
}

/// Full testbed specification.
#[derive(Debug, Clone)]
pub struct TestbedSpec {
    pub submit_nic_gbps: f64,
    pub workers: Vec<WorkerSpec>,
    pub wan: Option<WanSpec>,
    /// Submit node runs behind the Calico VPN overlay (unprivileged pod).
    pub vpn_on_submit: bool,
    /// Width of the throughput monitor bins on the submit NIC.
    pub monitor_bin: SimTime,
}

impl TestbedSpec {
    /// §III LAN test: 6 workers × 100 Gbps NIC, 200 slots total.
    pub fn lan_paper() -> TestbedSpec {
        TestbedSpec {
            submit_nic_gbps: 100.0,
            workers: (0..6)
                .map(|i| WorkerSpec {
                    nic_gbps: 100.0,
                    // 200 slots over 6 nodes: 34,34,33,33,33,33
                    slots: if i < 2 { 34 } else { 33 },
                })
                .collect(),
            wan: None,
            vpn_on_submit: false,
            monitor_bin: SimTime::from_secs(60),
        }
    }

    /// §IV WAN test: NY workers, 1×100 Gbps + 4×10 Gbps, 58 ms RTT.
    pub fn wan_paper() -> TestbedSpec {
        let mut workers = vec![WorkerSpec {
            nic_gbps: 100.0,
            slots: 120,
        }];
        workers.extend((0..4).map(|_| WorkerSpec {
            nic_gbps: 10.0,
            slots: 20,
        }));
        TestbedSpec {
            submit_nic_gbps: 100.0,
            workers,
            wan: Some(WanSpec {
                rtt_s: calib::WAN_RTT_S,
                loss: calib::WAN_LOSS,
                backbone_gbps: 100.0,
            }),
            vpn_on_submit: false,
            monitor_bin: SimTime::from_secs(60),
        }
    }

    /// §II VPN ablation: LAN deployment, submit pod behind Calico.
    pub fn lan_vpn_paper() -> TestbedSpec {
        TestbedSpec {
            vpn_on_submit: true,
            ..TestbedSpec::lan_paper()
        }
    }

    pub fn total_slots(&self) -> u32 {
        self.workers.iter().map(|w| w.slots).sum()
    }
}

/// A built testbed: the NetSim plus the link handles the engine needs.
#[derive(Debug)]
pub struct Testbed {
    pub net: NetSim,
    pub spec: TestbedSpec,
    pub submit_tx: LinkId,
    pub submit_vpn: Option<LinkId>,
    pub backbone: Option<LinkId>,
    pub worker_rx: Vec<LinkId>,
}

impl Testbed {
    pub fn build(spec: TestbedSpec) -> Testbed {
        let mut net = NetSim::new();
        let eff = calib::NIC_PROTOCOL_EFFICIENCY;

        let submit_vpn = spec.vpn_on_submit.then(|| {
            net.add_link("submit.vpn", Gbps(calib::VPN_PROCESSING_GBPS))
        });
        let submit_tx = net.add_link("submit.nic.tx", Gbps(spec.submit_nic_gbps * eff));
        net.monitor_link(submit_tx, spec.monitor_bin);

        let backbone = spec
            .wan
            .map(|w| net.add_link("backbone", Gbps(w.backbone_gbps * eff)));

        let worker_rx = spec
            .workers
            .iter()
            .enumerate()
            .map(|(i, w)| net.add_link(&format!("worker{i}.nic.rx"), Gbps(w.nic_gbps * eff)))
            .collect();

        Testbed {
            net,
            spec,
            submit_tx,
            submit_vpn,
            backbone,
            worker_rx,
        }
    }

    /// Links crossed by a submit -> worker transfer.
    pub fn path_to_worker(&self, worker: usize) -> Vec<LinkId> {
        let mut p = Vec::with_capacity(4);
        if let Some(v) = self.submit_vpn {
            p.push(v);
        }
        p.push(self.submit_tx);
        if let Some(b) = self.backbone {
            p.push(b);
        }
        p.push(self.worker_rx[worker]);
        p
    }

    /// Links crossed by a worker -> submit transfer (job output). The same
    /// resources are crossed in the reverse direction; NIC duplex is
    /// approximated as shared capacity, which is conservative and matches
    /// the submit node being the hot spot.
    pub fn path_from_worker(&self, worker: usize) -> Vec<LinkId> {
        let mut p = self.path_to_worker(worker);
        p.reverse();
        p
    }

    /// TCP path profile for transfers to any worker in this testbed.
    pub fn path_profile(&self) -> PathProfile {
        match self.spec.wan {
            None => PathProfile::lan(),
            Some(w) => PathProfile {
                rtt_s: w.rtt_s,
                loss: w.loss,
                window_bytes: calib::TCP_WINDOW_BYTES,
                endpoint_bps: calib::PER_STREAM_ENDPOINT_BPS,
            },
        }
    }

    /// Background-traffic parameters for the shared path, if any:
    /// (link, mean utilization, sd, step seconds, nominal Gbps).
    pub fn background(&self) -> Option<(LinkId, f64, f64, f64, f64)> {
        let eff = calib::NIC_PROTOCOL_EFFICIENCY;
        match (self.backbone, self.spec.wan) {
            (Some(b), Some(w)) => Some((
                b,
                calib::WAN_BG_MEAN,
                calib::WAN_BG_SD,
                calib::WAN_BG_STEP_S,
                w.backbone_gbps * eff,
            )),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lan_paper_shape() {
        let spec = TestbedSpec::lan_paper();
        assert_eq!(spec.workers.len(), 6);
        assert_eq!(spec.total_slots(), 200);
        let tb = Testbed::build(spec);
        assert!(tb.backbone.is_none());
        assert!(tb.submit_vpn.is_none());
        assert_eq!(tb.worker_rx.len(), 6);
        let p = tb.path_to_worker(3);
        assert_eq!(p, vec![tb.submit_tx, tb.worker_rx[3]]);
    }

    #[test]
    fn wan_paper_shape() {
        let spec = TestbedSpec::wan_paper();
        assert_eq!(spec.total_slots(), 200);
        assert_eq!(spec.workers[0].nic_gbps, 100.0);
        assert_eq!(spec.workers[4].nic_gbps, 10.0);
        let tb = Testbed::build(spec);
        let p = tb.path_to_worker(0);
        assert_eq!(p.len(), 3, "submit tx + backbone + worker rx");
        assert!((tb.path_profile().rtt_s - 0.058).abs() < 1e-9);
    }

    #[test]
    fn vpn_adds_processing_hop() {
        let tb = Testbed::build(TestbedSpec::lan_vpn_paper());
        let p = tb.path_to_worker(0);
        assert_eq!(p.len(), 3, "vpn + submit tx + worker rx");
        let vpn = tb.submit_vpn.unwrap();
        assert_eq!(p[0], vpn);
        // VPN capacity is the paper's observed 25 Gbps ceiling.
        let cap = tb.net.link(vpn).capacity_bps * 8.0 / 1e9;
        assert!((cap - 25.0).abs() < 1e-9);
    }

    #[test]
    fn nic_derated_by_protocol_efficiency() {
        let tb = Testbed::build(TestbedSpec::lan_paper());
        let cap_gbps = tb.net.link(tb.submit_tx).capacity_bps * 8.0 / 1e9;
        assert!((cap_gbps - 91.0).abs() < 0.01);
    }

    #[test]
    fn reverse_path() {
        let tb = Testbed::build(TestbedSpec::wan_paper());
        let fwd = tb.path_to_worker(1);
        let mut rev = tb.path_from_worker(1);
        rev.reverse();
        assert_eq!(fwd, rev);
    }

    #[test]
    fn background_only_on_wan() {
        let lan = Testbed::build(TestbedSpec::lan_paper());
        assert!(lan.background().is_none());
        let wan = Testbed::build(TestbedSpec::wan_paper());
        let (link, mean, _, _, nominal) = wan.background().unwrap();
        assert_eq!(link, wan.backbone.unwrap());
        assert!(mean > 0.0 && nominal > 90.0);
    }
}
