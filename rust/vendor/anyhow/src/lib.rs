//! Offline shim of the `anyhow` API surface htcdm uses.
//!
//! The build environment has no crates.io access, so this vendored crate
//! provides the subset we need with compatible semantics: an opaque
//! [`Error`] convertible from any `std::error::Error`, the [`anyhow!`] /
//! [`bail!`] macros, a [`Context`] extension trait, and `Result<T>`.
//!
//! Formatting matches anyhow's conventions where tests rely on them:
//! `Display` shows the outermost message, `{:#}` shows the whole context
//! chain joined by `": "`, and `Debug` shows the chain with a
//! `Caused by:` trailer.

use std::error::Error as StdError;
use std::fmt;

/// An opaque error: a root cause plus a stack of context messages.
pub struct Error {
    /// Root message (always present; mirrors the root cause's Display).
    root: String,
    /// Original typed cause, when constructed from a `std::error::Error`.
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
    /// Context messages, innermost first.
    contexts: Vec<String>,
}

impl Error {
    /// Construct from a plain message (the `anyhow!` macro entry point).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            root: message.to_string(),
            source: None,
            contexts: Vec::new(),
        }
    }

    /// Construct from a typed error, preserving it as the source.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error {
            root: error.to_string(),
            source: Some(Box::new(error)),
            contexts: Vec::new(),
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.contexts.push(context.to_string());
        self
    }

    /// The root cause, if this error wraps a typed `std::error::Error`.
    pub fn source_ref(&self) -> Option<&(dyn StdError + Send + Sync + 'static)> {
        self.source.as_deref()
    }

    /// Outermost message (what `Display` shows).
    fn outermost(&self) -> &str {
        self.contexts.last().map(String::as_str).unwrap_or(&self.root)
    }

    /// Messages outermost-to-innermost, ending at the root.
    fn chain_strings(&self) -> impl Iterator<Item = &str> {
        self.contexts
            .iter()
            .rev()
            .map(String::as_str)
            .chain(std::iter::once(self.root.as_str()))
    }

    /// Downcast a reference to the original typed cause.
    pub fn downcast_ref<E: StdError + 'static>(&self) -> Option<&E> {
        self.source.as_deref().and_then(|s| s.downcast_ref::<E>())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            let mut first = true;
            for part in self.chain_strings() {
                if !first {
                    f.write_str(": ")?;
                }
                f.write_str(part)?;
                first = false;
            }
            Ok(())
        } else {
            f.write_str(self.outermost())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.outermost())?;
        let rest: Vec<&str> = self.chain_strings().skip(1).collect();
        if !rest.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, part) in rest.iter().enumerate() {
                write!(f, "\n    {i}: {part}")?;
            }
        }
        Ok(())
    }
}

// NOTE: like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that keeps the blanket `From` below coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context()` / `.with_context()`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for std::result::Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "root cause")
    }

    #[test]
    fn display_shows_outermost_context() {
        let e: Error = Error::new(io_err()).context("outer");
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: root cause");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "root cause");
    }

    #[test]
    fn context_trait_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("while frobbing").unwrap_err();
        assert_eq!(format!("{e:#}"), "while frobbing: root cause");

        let o: Option<u32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero not allowed (got {x})");
            }
            ensure!(x < 10, "too big: {x}");
            Ok(x)
        }
        assert_eq!(f(0).unwrap_err().to_string(), "zero not allowed (got 0)");
        assert_eq!(f(12).unwrap_err().to_string(), "too big: 12");
        assert_eq!(f(3).unwrap(), 3);
        let e = anyhow!("plain {}", 42);
        assert_eq!(e.to_string(), "plain 42");
    }

    #[test]
    fn debug_includes_cause_chain() {
        let e = Error::new(io_err()).context("inner ctx").context("outer ctx");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer ctx"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("root cause"));
    }

    #[test]
    fn downcast_ref_recovers_typed_cause() {
        let e = Error::new(io_err()).context("ctx");
        assert!(e.downcast_ref::<std::io::Error>().is_some());
    }
}
