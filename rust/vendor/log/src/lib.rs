//! Offline shim of the `log` facade: the five level macros, printing to
//! stderr when `HTCDM_LOG` is set in the environment (any value). No
//! logger registration, no level filtering — htcdm only needs best-effort
//! diagnostics from daemon threads.

use std::fmt;

/// Emit one log line if logging is enabled. Called by the macros.
pub fn __emit(level: &str, args: fmt::Arguments<'_>) {
    if std::env::var_os("HTCDM_LOG").is_some() {
        eprintln!("[{level:>5}] {args}");
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::__emit("ERROR", ::std::format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::__emit("WARN", ::std::format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::__emit("INFO", ::std::format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::__emit("DEBUG", ::std::format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::__emit("TRACE", ::std::format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macros_accept_format_args_without_panicking() {
        let err = std::io::Error::new(std::io::ErrorKind::Other, "x");
        crate::error!("job {} failed: {err}", 3);
        crate::warn!("w {:#}", 1);
        crate::info!("i");
        crate::debug!("d {}", "s");
        crate::trace!("t");
    }
}
