//! Bench: the durable-task auto-tuner closing the loop on the static
//! `CHUNK` knob (`benches/chunk_sweep.rs` sweeps it by hand; the tuner
//! walks it — and the concurrency cap — from observed per-window
//! goodput).
//!
//! * a SIM sweep: the same 32 x 50 MB task run with the tuner off
//!   (static concurrency 1) and on, from several starting chunk sizes —
//!   reporting makespan, the knob trajectory endpoints, and the
//!   tuned-vs-static speedup, asserted > 1x in-bench so CI fails if the
//!   tuner stops climbing,
//! * a REAL row: a small loopback task with the tuner on, proving the
//!   trajectory is recorded while real sealed bytes move.
//!
//! Every row is also recorded as a JSON object; set `BENCH_REPORT_DIR`
//! to write them to `task_autotune.json` (the CI bench-smoke job uploads
//! them as artifacts).
//!
//! Run: cargo bench --bench task_autotune
//! CI smoke: cargo bench --bench task_autotune -- --smoke

use htcdm::coordinator::engine::{run_task_sim, EngineSpec};
use htcdm::fabric::{run_real_task, RealTaskConfig};
use htcdm::mover::{TaskJournal, TaskRunner, TransferTask};
use htcdm::netsim::topology::TestbedSpec;
use htcdm::transfer::ThrottlePolicy;

/// `--smoke` (or `BENCH_SMOKE=1`): shrink the sweep so CI can execute
/// the bench end-to-end on each PR. The speedup gate still runs.
fn smoke_mode() -> bool {
    std::env::args().any(|a| a == "--smoke") || std::env::var_os("BENCH_SMOKE").is_some()
}

const N_FILES: usize = 32;
const FILE_BYTES: u64 = 50_000_000;

fn sim_spec() -> EngineSpec {
    EngineSpec::paper(TestbedSpec::lan_paper(), ThrottlePolicy::Disabled)
}

fn sim_task(autotune: bool, chunk_words: usize) -> TransferTask {
    TransferTask::new("bench-task", "alice")
        .with_uniform_files("input", N_FILES, FILE_BYTES)
        .with_concurrency(1)
        .with_chunk_words(chunk_words)
        .with_autotune(autotune)
        .with_tune_window_s(0.2)
}

fn run_sim(autotune: bool, chunk_words: usize) -> anyhow::Result<(f64, u32, usize, usize)> {
    let mut runner = TaskRunner::new(sim_task(autotune, chunk_words), TaskJournal::memory())?;
    let r = run_task_sim(&sim_spec(), &mut runner)?;
    anyhow::ensure!(
        r.progress.files_done == N_FILES,
        "sim task incomplete: {}/{N_FILES}",
        r.progress.files_done
    );
    Ok((
        r.makespan_s,
        r.progress.concurrency,
        r.progress.chunk_words,
        r.tuner.len(),
    ))
}

fn main() -> anyhow::Result<()> {
    let smoke = smoke_mode();
    let mut json_rows: Vec<String> = Vec::new();
    if smoke {
        println!("[smoke mode: one starting chunk size]");
    }

    println!(
        "=== task auto-tuner vs static knobs (sim, {N_FILES} x {} MB) ===",
        FILE_BYTES / 1_000_000
    );
    println!("  mode        chunk0    makespan    final conc  final chunk  windows");
    let (static_makespan, _, _, _) = run_sim(false, 1024)?;
    println!(
        "  static        1024   {static_makespan:>8.2} s   {:>9}   {:>9}   {:>6}",
        1, 1024, 0
    );
    json_rows.push(format!(
        "{{\"section\":\"sim\",\"mode\":\"static\",\"chunk0\":1024,\
         \"makespan_s\":{static_makespan:.3},\"final_concurrency\":1,\
         \"final_chunk_words\":1024,\"windows\":0}}"
    ));

    let chunk0s: &[usize] = if smoke { &[1024] } else { &[256, 1024, 16384] };
    for &chunk0 in chunk0s {
        let (makespan, conc, chunk, windows) = run_sim(true, chunk0)?;
        let speedup = static_makespan / makespan.max(1e-9);
        println!(
            "  autotune    {chunk0:>6}   {makespan:>8.2} s   {conc:>9}   {chunk:>9}   \
             {windows:>6}   ({speedup:.2}x vs static)"
        );
        json_rows.push(format!(
            "{{\"section\":\"sim\",\"mode\":\"autotune\",\"chunk0\":{chunk0},\
             \"makespan_s\":{makespan:.3},\"final_concurrency\":{conc},\
             \"final_chunk_words\":{chunk},\"windows\":{windows},\
             \"speedup_vs_static\":{speedup:.3}}}"
        ));
        // The climb gate: from concurrency 1 the tuner must beat the
        // static knobs it started with, or the loop is broken.
        anyhow::ensure!(
            makespan < static_makespan,
            "tuner never beat static knobs from chunk0={chunk0}: \
             {makespan:.2}s vs {static_makespan:.2}s"
        );
        anyhow::ensure!(windows >= 2, "tuner recorded {windows} windows");
    }

    println!("\n=== real loopback task with the tuner on ===");
    let task = TransferTask::new("bench-task-real", "alice")
        .with_uniform_files("input", 8, 256 << 10)
        .with_concurrency(1)
        .with_autotune(true)
        .with_tune_window_s(0.05);
    let runner = TaskRunner::new(task, TaskJournal::memory())?;
    let cfg = RealTaskConfig {
        workers: 4,
        chunk_words: 1024,
        passphrase: "bench".into(),
        ..RealTaskConfig::default()
    };
    let (r, _runner) = run_real_task(&cfg, runner)?;
    anyhow::ensure!(r.errors == 0, "real task errors: {}", r.errors);
    anyhow::ensure!(r.progress.files_done == 8, "real task incomplete");
    println!(
        "  8 x 256 KiB | {:.2} s wall | final concurrency {} | {} tuner windows",
        r.wall_secs,
        r.progress.concurrency,
        r.tuner.len()
    );
    json_rows.push(format!(
        "{{\"section\":\"real\",\"files\":8,\"file_bytes\":{},\
         \"wall_secs\":{:.3},\"final_concurrency\":{},\"windows\":{}}}",
        256 << 10,
        r.wall_secs,
        r.progress.concurrency,
        r.tuner.len()
    ));

    if let Ok(dir) = std::env::var("BENCH_REPORT_DIR") {
        std::fs::create_dir_all(&dir).ok();
        let path = format!("{dir}/task_autotune.json");
        std::fs::write(&path, format!("[{}]\n", json_rows.join(",\n ")))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}
