//! Bench: sealed-stream goodput of the zero-copy byte data path.
//!
//! Three sections, each emitted as JSON rows (set `BENCH_REPORT_DIR` to
//! write them to `stream_goodput.json`; schema in docs/REPORTS.md):
//!
//! * per-core send goodput — `send_stream` into a null sink, per cipher
//!   and chunk size (seal cost + framing, no socket),
//! * per-core recv goodput — `recv_stream` from a prebuilt wire image,
//! * a loopback single-stream row over a real TCP socket at the default
//!   64 KiB chunk: the pre-PR word-path code (kept verbatim in the
//!   `legacy` module below) vs the zero-copy v2 path, gated in-bench at
//!   `MIN_RATIO`x so CI fails if the byte path regresses to word-path
//!   speeds. See docs/ARCHITECTURE.md §Data-path performance.
//!
//! Run: cargo bench --bench stream_goodput
//! CI smoke: cargo bench --bench stream_goodput -- --smoke

use htcdm::runtime::engine::NativeEngine;
use htcdm::security::Method;
use htcdm::transfer::stream::{
    recv_stream, seal_threads_from_env, send_stream, send_stream_opts, StreamOpts,
    DEFAULT_CHUNK_WORDS, V2,
};
use htcdm::util::Prng;
use std::io::{BufReader, IoSlice, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Instant;

/// The zero-copy loopback stream must beat the pre-PR word path by at
/// least this factor at the default chunk size, or the bench errors.
const MIN_RATIO: f64 = 2.0;

/// The pre-PR word-path sender/receiver, copied verbatim so the
/// baseline stays honest as the crate evolves. The word-level seal
/// functions it drives (`chacha::seal_chunk` and friends) are the
/// crate's frozen scalar reference, so this is exactly the data path
/// shipped before the byte-path rewrite.
mod legacy {
    use anyhow::{bail, Context, Result};
    use htcdm::runtime::engine::{Kind, SealEngine};
    use htcdm::security::chacha::bytes_to_words;
    use htcdm::transfer::stream::{StreamStats, MAGIC};
    use std::io::{Read, Write};

    fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
        w.write_all(&v.to_le_bytes()).context("write u32")
    }

    fn read_u32(r: &mut impl Read) -> Result<u32> {
        let mut b = [0u8; 4];
        r.read_exact(&mut b).context("read u32")?;
        Ok(u32::from_le_bytes(b))
    }

    pub fn send_stream_words(
        w: &mut impl Write,
        engine: &mut dyn SealEngine,
        key: &[u32; 8],
        nonce: &[u32; 3],
        data: &[u8],
        chunk_words: usize,
    ) -> Result<StreamStats> {
        assert!(chunk_words % 16 == 0 && chunk_words > 0);
        let mut stats = StreamStats::default();
        w.write_all(MAGIC)?;
        write_u32(w, 1)?;
        w.write_all(&(data.len() as u64).to_le_bytes())?;
        write_u32(w, chunk_words as u32)?;
        stats.wire_bytes += 4 + 4 + 8 + 4;
        let words = bytes_to_words(data);
        let mut counter0: u32 = 0;
        let mut frame_buf: Vec<u8> = Vec::with_capacity(chunk_words * 4 + 32);
        for chunk in words.chunks(chunk_words) {
            let mut buf = chunk.to_vec();
            let digest = engine.process(Kind::Seal, key, nonce, counter0, &mut buf)?;
            frame_buf.clear();
            frame_buf.extend_from_slice(&counter0.to_le_bytes());
            frame_buf.extend_from_slice(&(buf.len() as u32).to_le_bytes());
            for word in &buf {
                frame_buf.extend_from_slice(&word.to_le_bytes());
            }
            for d in &digest {
                frame_buf.extend_from_slice(&d.to_le_bytes());
            }
            w.write_all(&frame_buf)?;
            stats.wire_bytes += 8 + buf.len() as u64 * 4 + 16;
            stats.frames += 1;
            counter0 = counter0.wrapping_add((buf.len() / 16) as u32);
        }
        stats.payload_bytes = data.len() as u64;
        w.flush()?;
        Ok(stats)
    }

    pub fn recv_stream_words(
        r: &mut impl Read,
        engine: &mut dyn SealEngine,
        key: &[u32; 8],
        nonce: &[u32; 3],
    ) -> Result<(Vec<u8>, StreamStats)> {
        let mut stats = StreamStats::default();
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic).context("read magic")?;
        if &magic != MAGIC {
            bail!("bad stream magic {magic:?}");
        }
        let version = read_u32(r)?;
        if version != 1 {
            bail!("unsupported stream version {version}");
        }
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b8).context("read u64")?;
        let file_bytes = u64::from_le_bytes(b8) as usize;
        let chunk_words = read_u32(r)? as usize;
        if chunk_words == 0 || chunk_words % 16 != 0 || chunk_words > (1 << 24) {
            bail!("bad chunk_words {chunk_words}");
        }
        stats.wire_bytes += 4 + 4 + 8 + 4;
        let total_words = file_bytes.div_ceil(64) * 16;
        let mut bytes: Vec<u8> = Vec::with_capacity(total_words * 4);
        let mut received_words = 0usize;
        let mut expect_counter: u32 = 0;
        let mut byte_buf: Vec<u8> = Vec::new();
        let mut frame_words: Vec<u32> = Vec::new();
        while received_words < total_words {
            let counter0 = read_u32(r)?;
            if counter0 != expect_counter {
                bail!("frame counter {counter0} != expected {expect_counter}");
            }
            let n_words = read_u32(r)? as usize;
            if n_words == 0 || n_words % 16 != 0 || n_words > chunk_words {
                bail!("bad frame n_words {n_words}");
            }
            byte_buf.resize(n_words * 4, 0);
            r.read_exact(&mut byte_buf).context("read frame payload")?;
            frame_words.clear();
            frame_words.extend(
                byte_buf
                    .chunks_exact(4)
                    .map(|c| u32::from_le_bytes(c.try_into().unwrap())),
            );
            let mut digest = [0u32; 4];
            for d in digest.iter_mut() {
                *d = read_u32(r)?;
            }
            let computed = engine.process(Kind::Unseal, key, nonce, counter0, &mut frame_words)?;
            if computed != digest {
                bail!("integrity failure at counter {counter0}");
            }
            stats.wire_bytes += 8 + n_words as u64 * 4 + 16;
            stats.frames += 1;
            expect_counter = expect_counter.wrapping_add((n_words / 16) as u32);
            received_words += n_words;
            for w in &frame_words {
                bytes.extend_from_slice(&w.to_le_bytes());
            }
        }
        bytes.truncate(file_bytes);
        stats.payload_bytes = file_bytes as u64;
        Ok((bytes, stats))
    }
}

fn smoke_mode() -> bool {
    std::env::args().any(|a| a == "--smoke") || std::env::var_os("BENCH_SMOKE").is_some()
}

/// Discards everything, including vectored writes, so send benchmarks
/// measure sealing + framing without a socket.
struct NullWriter;

impl Write for NullWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        Ok(buf.len())
    }
    fn write_vectored(&mut self, bufs: &[IoSlice<'_>]) -> std::io::Result<usize> {
        Ok(bufs.iter().map(|b| b.len()).sum())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

fn payload(bytes: usize) -> Vec<u8> {
    let mut rng = Prng::new(42);
    (0..bytes).map(|_| rng.next_u32() as u8).collect()
}

fn method_name(m: Method) -> &'static str {
    match m {
        Method::Chacha20 => "chacha20",
        Method::Aes256Ctr => "aes256ctr",
        Method::Plain => "plain",
    }
}

fn bench_send(method: Method, chunk_words: usize, data: &[u8], secs: f64) -> anyhow::Result<f64> {
    let mut engine = NativeEngine::new(method);
    let key = [7u32; 8];
    let nonce = [1, 2, 3];
    send_stream(&mut NullWriter, &mut engine, &key, &nonce, data, chunk_words)?;
    let t0 = Instant::now();
    let mut bytes = 0u64;
    while t0.elapsed().as_secs_f64() < secs {
        send_stream(&mut NullWriter, &mut engine, &key, &nonce, data, chunk_words)?;
        bytes += data.len() as u64;
    }
    Ok(bytes as f64 * 8.0 / t0.elapsed().as_secs_f64() / 1e9)
}

fn bench_recv(method: Method, chunk_words: usize, data: &[u8], secs: f64) -> anyhow::Result<f64> {
    let mut engine = NativeEngine::new(method);
    let key = [7u32; 8];
    let nonce = [1, 2, 3];
    let mut wire = Vec::new();
    send_stream(&mut wire, &mut engine, &key, &nonce, data, chunk_words)?;
    let (out, _) = recv_stream(&mut std::io::Cursor::new(&wire), &mut engine, &key, &nonce)?;
    anyhow::ensure!(out == data, "recv bench roundtrip mismatch");
    let t0 = Instant::now();
    let mut bytes = 0u64;
    while t0.elapsed().as_secs_f64() < secs {
        recv_stream(&mut std::io::Cursor::new(&wire), &mut engine, &key, &nonce)?;
        bytes += data.len() as u64;
    }
    Ok(bytes as f64 * 8.0 / t0.elapsed().as_secs_f64() / 1e9)
}

/// One sealed transfer over a real loopback socket; returns goodput in
/// Gbps measured wall-to-wall on the receiving side (connect to last
/// payload byte, so the sender's sealing is on the clock too).
fn loopback_once(data: &Arc<Vec<u8>>, legacy_path: bool) -> anyhow::Result<f64> {
    let key = [7u32; 8];
    let nonce = [4, 5, 6];
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let tx_data = Arc::clone(data);
    let server = std::thread::spawn(move || -> anyhow::Result<()> {
        let (mut sock, _) = listener.accept()?;
        let mut engine = NativeEngine::new(Method::Chacha20);
        if legacy_path {
            legacy::send_stream_words(
                &mut sock,
                &mut engine,
                &key,
                &nonce,
                &tx_data,
                DEFAULT_CHUNK_WORDS,
            )?;
        } else {
            let opts = StreamOpts {
                chunk_words: DEFAULT_CHUNK_WORDS,
                seal_threads: seal_threads_from_env(),
                version: V2,
            };
            send_stream_opts(&mut sock, &mut engine, &key, &nonce, &tx_data, &opts)?;
        }
        Ok(())
    });
    let t0 = Instant::now();
    let sock = TcpStream::connect(addr)?;
    let mut r = BufReader::with_capacity(1 << 20, sock);
    let mut engine = NativeEngine::new(Method::Chacha20);
    let out = if legacy_path {
        legacy::recv_stream_words(&mut r, &mut engine, &key, &nonce)?.0
    } else {
        recv_stream(&mut r, &mut engine, &key, &nonce)?.0
    };
    let secs = t0.elapsed().as_secs_f64();
    server
        .join()
        .map_err(|_| anyhow::anyhow!("loopback sender panicked"))??;
    anyhow::ensure!(out == **data, "loopback payload mismatch");
    Ok(data.len() as f64 * 8.0 / secs / 1e9)
}

fn loopback_best(data: &Arc<Vec<u8>>, legacy_path: bool, reps: usize) -> anyhow::Result<f64> {
    let mut best = 0.0f64;
    for _ in 0..reps {
        best = best.max(loopback_once(data, legacy_path)?);
    }
    Ok(best)
}

fn main() -> anyhow::Result<()> {
    let smoke = smoke_mode();
    let mut json_rows: Vec<String> = Vec::new();
    let core_bytes = if smoke { 2 << 20 } else { 8 << 20 };
    let secs = if smoke { 0.2 } else { 1.0 };
    let data = payload(core_bytes);
    if smoke {
        println!("[smoke mode: small payloads, short windows]");
    }

    println!("=== per-core sealed-stream goodput (zero-copy byte path, single thread) ===");
    println!("  dir   cipher       chunk        Gbps");
    for method in [Method::Chacha20, Method::Aes256Ctr] {
        for chunk_words in [4096usize, 16384, 65536] {
            let tx = bench_send(method, chunk_words, &data, secs)?;
            let rx = bench_recv(method, chunk_words, &data, secs)?;
            let kib = chunk_words * 4 / 1024;
            println!("  send  {:<10} {kib:>5} KiB  {tx:>8.3}", method_name(method));
            println!("  recv  {:<10} {kib:>5} KiB  {rx:>8.3}", method_name(method));
            for (dir, gbps) in [("send", tx), ("recv", rx)] {
                json_rows.push(format!(
                    "{{\"section\":\"per_core\",\"dir\":\"{dir}\",\"method\":\"{}\",\
                     \"chunk_words\":{chunk_words},\"gbps\":{gbps:.3}}}",
                    method_name(method)
                ));
            }
        }
    }

    let loop_bytes = if smoke { 16 << 20 } else { 64 << 20 };
    let reps = if smoke { 2 } else { 3 };
    let loop_data = Arc::new(payload(loop_bytes));
    println!("\n=== loopback single stream (ChaCha20, 64 KiB chunk, best of {reps}) ===");
    let baseline = loopback_best(&loop_data, true, reps)?;
    let v2 = loopback_best(&loop_data, false, reps)?;
    let ratio = v2 / baseline.max(1e-9);
    let threads = seal_threads_from_env();
    println!("  legacy v1 word path   {baseline:>8.3} Gbps");
    println!("  zero-copy v2 path     {v2:>8.3} Gbps  (SEAL_THREADS={threads})");
    println!("  speedup               {ratio:>8.2}x  (gate: >= {MIN_RATIO}x)");
    for (path, gbps) in [("legacy_v1_words", baseline), ("zero_copy_v2", v2)] {
        json_rows.push(format!(
            "{{\"section\":\"loopback\",\"path\":\"{path}\",\"payload_bytes\":{loop_bytes},\
             \"seal_threads\":{threads},\"gbps\":{gbps:.3}}}"
        ));
    }
    json_rows.push(format!(
        "{{\"section\":\"gate\",\"baseline_gbps\":{baseline:.3},\"v2_gbps\":{v2:.3},\
         \"ratio\":{ratio:.3},\"min_ratio\":{MIN_RATIO}}}"
    ));

    if let Ok(dir) = std::env::var("BENCH_REPORT_DIR") {
        std::fs::create_dir_all(&dir).ok();
        let path = format!("{dir}/stream_goodput.json");
        std::fs::write(&path, format!("[{}]\n", json_rows.join(",\n ")))?;
        eprintln!("wrote {path}");
    }

    anyhow::ensure!(
        ratio >= MIN_RATIO,
        "zero-copy stream goodput regressed: {v2:.3} Gbps vs word-path {baseline:.3} Gbps \
         ({ratio:.2}x < {MIN_RATIO}x)"
    );
    Ok(())
}
