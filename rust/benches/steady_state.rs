//! Bench: §II steady-state sizing — the paper's concurrency arithmetic
//! validated against a simulated steady pool.
//!
//! Paper: "approximately 200 slots that need file transfer at any point in
//! time, which is what one would expect in a pool with 20k slots serving
//! jobs lasting 6 hours, each spending 3 minutes in file transfer."
//! Run: cargo bench --bench steady_state

use htcdm::coordinator::engine::EngineSpec;
use htcdm::coordinator::Experiment;
use htcdm::netsim::topology::TestbedSpec;
use htcdm::transfer::ThrottlePolicy;
use htcdm::util::units::Bytes;
use htcdm::workload::concurrent_transfers;

fn main() -> anyhow::Result<()> {
    println!("=== §II sizing: slots concurrently in file transfer ===");
    println!("  pool     job len   xfer len   Little's-law concurrency");
    for (slots, job_h, xfer_min) in [
        (20_000u32, 6.0, 3.0),   // the paper's example
        (20_000, 6.0, 1.5),
        (20_000, 12.0, 3.0),
        (50_000, 6.0, 3.0),
        (10_000, 2.0, 3.0),
    ] {
        let c = concurrent_transfers(slots, job_h * 3600.0, xfer_min * 60.0);
        println!(
            "  {slots:>6}   {job_h:>4.1} h    {xfer_min:>4.1} min   {c:>7.1}{}",
            if (slots, job_h, xfer_min) == (20_000, 6.0, 3.0) {
                "   <- paper's ~200"
            } else {
                ""
            }
        );
    }

    // Validate in simulation: a steady pool where each slot's job cycle is
    // transfer + run, sized so ~1/12 of slots transfer at once (6 h vs
    // 3 min scaled down 60x to keep the run quick: 6 min jobs, 3 s xfer).
    println!("\n  simulation check (scaled 60x: 360 s jobs, ~3 s transfers, 200 slots):");
    let mut spec = EngineSpec::paper(TestbedSpec::lan_paper(), ThrottlePolicy::Disabled);
    spec.n_jobs = 2000;
    spec.input_bytes = Bytes(200_000_000); // ~1.5 s at per-stream cap
    spec.runtime_median_s = 360.0;
    let r = Experiment::custom("steady", spec).run()?;
    println!(
        "  peak concurrent transfers {} of 200 slots; sustained {:.1} Gbps (NIC no longer the bottleneck)",
        r.peak_concurrent_transfers,
        r.sustained_gbps()
    );
    Ok(())
}
