//! Bench: §III transfer-queue ablation + concurrency-cap sweep.
//!
//! Paper: with the default file-transfer queue (tuned for spinning disks)
//! the same 10k-job test took 64 min vs 32 min with it disabled (~2x).
//! The sweep shows where the throttle stops hurting — the design-choice
//! ablation DESIGN.md calls out.
//! Run: cargo bench --bench queue_ablation

use htcdm::coordinator::engine::EngineSpec;
use htcdm::coordinator::{Experiment, Scenario};
use htcdm::netsim::topology::TestbedSpec;
use htcdm::transfer::ThrottlePolicy;

fn main() -> anyhow::Result<()> {
    println!("=== §III ablation: file-transfer queue policies (10k x 2 GB LAN) ===");
    let tuned = Experiment::scenario(Scenario::LanPaper).run()?;
    let dflt = Experiment::scenario(Scenario::LanDefaultQueue).run()?;
    println!("{}", tuned.table_row(Some(90.0), Some(32.0)));
    println!("{}", dflt.table_row(None, Some(64.0)));
    println!(
        "  makespan ratio default/disabled: paper 2.0x, measured {:.2}x",
        dflt.makespan.as_secs_f64() / tuned.makespan.as_secs_f64()
    );
    println!("\n  concurrency-cap sweep (MaxConcurrent override):");
    println!("  cap    sustained   makespan    peak-active");
    for cap in [10u32, 20, 36, 50, 100, 200] {
        let spec = EngineSpec::paper(
            TestbedSpec::lan_paper(),
            ThrottlePolicy::MaxConcurrent(cap),
        );
        let r = Experiment::custom(&format!("cap{cap}"), spec).run()?;
        println!(
            "  {:>4}   {:>6.1} Gbps  {:>6.1} min  {:>4}",
            cap,
            r.sustained_gbps(),
            r.makespan.as_mins_f64(),
            r.peak_concurrent_transfers
        );
    }
    println!("  (the knee sits where cap x per-stream 1.1 Gbps crosses the 91 Gbps NIC)");
    Ok(())
}
