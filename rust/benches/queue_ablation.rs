//! Bench: §III transfer-queue ablation + concurrency-cap sweep, plus the
//! data-mover sweeps the unified subsystem unlocks.
//!
//! Paper: with the default file-transfer queue (tuned for spinning disks)
//! the same 10k-job test took 64 min vs 32 min with it disabled (~2x).
//! The sweep shows where the throttle stops hurting — the design-choice
//! ablation DESIGN.md calls out.
//!
//! New sections:
//! * an admission-POLICY sweep on the simulator (same workload, five
//!   policies through the same mover),
//! * a shadow-SHARD sweep on the real loopback fabric: N per-shard seal
//!   engines vs the paper-faithful single crypto funnel. With N > 1 the
//!   parallel sealing beats the single-funnel baseline,
//! * a SUBMIT-NODE sweep (1/2/4/8) on the real loopback fabric: the
//!   scale-out throughput of N file servers behind the pool router vs
//!   the paper's single submit node, and
//! * a DATA-SOURCE sweep (funnel vs dedicated DTNs) on the real
//!   loopback fabric: the offload win of serving bytes from a DTN
//!   fleet while the submit node keeps only scheduling duties.
//!
//! Every sweep row is also recorded as a JSON object; set
//! `BENCH_REPORT_DIR` to write them to `queue_ablation.json` (the CI
//! bench-smoke job uploads them as artifacts).
//!
//! Run: cargo bench --bench queue_ablation
//! CI smoke: cargo bench --bench queue_ablation -- --smoke
//! (single-iteration, 1/100-scale pass so the bench can't bit-rot)

use htcdm::coordinator::engine::EngineSpec;
use htcdm::coordinator::{Experiment, Scenario};
use htcdm::fabric::{run_real_pool, RealPoolConfig};
use htcdm::mover::{AdmissionConfig, RouterPolicy, SourcePlan, SourceSelector};
use htcdm::netsim::topology::TestbedSpec;
use htcdm::transfer::ThrottlePolicy;

/// `--smoke` (or `BENCH_SMOKE=1`): shrink every sweep to a single cheap
/// point so CI can execute the bench end-to-end on each PR.
fn smoke_mode() -> bool {
    std::env::args().any(|a| a == "--smoke") || std::env::var_os("BENCH_SMOKE").is_some()
}

fn main() -> anyhow::Result<()> {
    let smoke = smoke_mode();
    let sim_scale = if smoke { 100 } else { 1 };
    // One JSON object per sweep row, written to
    // $BENCH_REPORT_DIR/queue_ablation.json at the end.
    let mut json_rows: Vec<String> = Vec::new();
    if smoke {
        println!("[smoke mode: 1/100-scale sims, single-point sweeps]");
    }
    println!("=== §III ablation: file-transfer queue policies (10k x 2 GB LAN) ===");
    let tuned = Experiment::scenario(Scenario::LanPaper).scaled(sim_scale).run()?;
    let dflt = Experiment::scenario(Scenario::LanDefaultQueue)
        .scaled(sim_scale)
        .run()?;
    println!("{}", tuned.table_row(Some(90.0), Some(32.0)));
    println!("{}", dflt.table_row(None, Some(64.0)));
    println!(
        "  makespan ratio default/disabled: paper 2.0x, measured {:.2}x",
        dflt.makespan.as_secs_f64() / tuned.makespan.as_secs_f64()
    );
    println!("\n  concurrency-cap sweep (MaxConcurrent override):");
    println!("  cap    sustained   makespan    peak-active");
    let caps: &[u32] = if smoke { &[36] } else { &[10, 20, 36, 50, 100, 200] };
    for &cap in caps {
        let spec = EngineSpec::paper(
            TestbedSpec::lan_paper(),
            ThrottlePolicy::MaxConcurrent(cap),
        );
        let r = Experiment::custom(&format!("cap{cap}"), spec)
            .scaled(sim_scale)
            .run()?;
        println!(
            "  {:>4}   {:>6.1} Gbps  {:>6.1} min  {:>4}",
            cap,
            r.sustained_gbps(),
            r.makespan.as_mins_f64(),
            r.peak_concurrent_transfers
        );
    }
    println!("  (the knee sits where cap x per-stream 1.1 Gbps crosses the 91 Gbps NIC)");

    println!("\n=== admission-policy sweep (same workload, 4 owners, 1/10 scale) ===");
    println!("  (inputs are the paper's uniform 2 GB, so weighted-by-size");
    println!("   degenerates to FIFO here — it differentiates on mixed sizes)");
    println!("  policy                     sustained   makespan    peak-active");
    let policies: [AdmissionConfig; 5] = [
        ThrottlePolicy::Disabled.into(),
        ThrottlePolicy::htcondor_default().into(),
        ThrottlePolicy::MaxConcurrent(100).into(),
        AdmissionConfig::FairShare { limit: 100 },
        AdmissionConfig::WeightedBySize { limit: 100 },
    ];
    for policy in policies {
        let mut e = Experiment::scenario(Scenario::LanPaper)
            .scaled(10.max(sim_scale))
            .with_policy(policy);
        e.spec.n_owners = 4;
        let r = e.run()?;
        println!(
            "  {:<24}   {:>6.1} Gbps  {:>6.1} min  {:>4}",
            r.policy,
            r.sustained_gbps(),
            r.makespan.as_mins_f64(),
            r.peak_concurrent_transfers
        );
    }

    println!("\n=== shadow-shard sweep (real loopback fabric, sealed bytes) ===");
    println!("  the single-funnel baseline (1 shard = the seed's one crypto thread)");
    println!("  vs per-shadow parallel sealing:");
    println!("  shards   goodput     wall      per-shard jobs");
    let mut baseline_gbps = 0.0;
    let mut best_gbps: f64 = 0.0;
    let shard_sweep: &[u32] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };
    for &shards in shard_sweep {
        let cfg = RealPoolConfig {
            n_jobs: if smoke { 8 } else { 32 },
            workers: 8,
            input_bytes: if smoke { 1 << 20 } else { 8 << 20 },
            output_bytes: 4096,
            use_xla_engine: false,
            passphrase: "ablation".into(),
            shadows: shards,
            ..Default::default()
        };
        let r = run_real_pool(cfg)?;
        anyhow::ensure!(r.errors == 0, "transfer errors in shard sweep");
        if shards == 1 {
            baseline_gbps = r.gbps;
        }
        if shards > 1 {
            best_gbps = best_gbps.max(r.gbps);
        }
        println!(
            "  {:>4}   {:>7.3} Gbps  {:>6.2} s   {:?}",
            shards, r.gbps, r.wall_secs, r.mover.admitted_per_shard
        );
        json_rows.push(format!(
            "{{\"sweep\":\"shards\",\"shards\":{},\"gbps\":{:.4},\"wall_secs\":{:.3}}}",
            shards, r.gbps, r.wall_secs
        ));
    }
    println!(
        "  multi-shard best vs single-funnel: {:.2}x",
        best_gbps / baseline_gbps
    );

    println!("\n=== submit-node sweep (real loopback fabric, scale-out) ===");
    println!("  one file server per submit node behind the round-robin pool");
    println!("  router vs the paper's single submit node:");
    println!("  nodes   goodput     wall      per-node jobs");
    let mut single_node_gbps = 0.0;
    let mut best_scaleout: f64 = 0.0;
    let node_sweep: &[u32] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };
    for &nodes in node_sweep {
        let cfg = RealPoolConfig {
            n_jobs: if smoke { 8 } else { 32 },
            workers: 8,
            input_bytes: if smoke { 1 << 20 } else { 8 << 20 },
            output_bytes: 4096,
            use_xla_engine: false,
            passphrase: "scale-out".into(),
            n_submit_nodes: nodes,
            router: RouterPolicy::RoundRobin,
            ..Default::default()
        };
        let r = run_real_pool(cfg)?;
        anyhow::ensure!(r.errors == 0, "transfer errors in submit-node sweep");
        if nodes == 1 {
            single_node_gbps = r.gbps;
        } else {
            best_scaleout = best_scaleout.max(r.gbps);
        }
        println!(
            "  {:>4}   {:>7.3} Gbps  {:>6.2} s   {:?}",
            nodes, r.gbps, r.wall_secs, r.router.routed_per_node
        );
        json_rows.push(format!(
            "{{\"sweep\":\"submit-nodes\",\"nodes\":{},\"gbps\":{:.4},\"wall_secs\":{:.3}}}",
            nodes, r.gbps, r.wall_secs
        ));
    }
    println!(
        "  scale-out best vs single submit node: {:.2}x",
        best_scaleout / single_node_gbps
    );

    println!("\n=== data-source sweep (real loopback fabric, funnel vs DTN offload) ===");
    println!("  the paper's submit funnel vs dedicated data nodes serving the");
    println!("  bytes while the submit node keeps only scheduling duties:");
    println!("  source            goodput     wall      submit MiB   dtn MiB");
    let mut funnel_gbps = 0.0;
    let mut dtn_gbps = 0.0;
    let source_sweep: &[(&str, u32, SourcePlan)] = if smoke {
        &[
            ("funnel", 0, SourcePlan::SubmitFunnel),
            ("dtn-2", 2, SourcePlan::DedicatedDtn),
        ]
    } else {
        &[
            ("funnel", 0, SourcePlan::SubmitFunnel),
            ("dtn-2", 2, SourcePlan::DedicatedDtn),
            ("dtn-4", 4, SourcePlan::DedicatedDtn),
            ("hybrid-4", 4, SourcePlan::Hybrid { threshold: 4 << 20 }),
        ]
    };
    for &(label, data_nodes, source) in source_sweep {
        let cfg = RealPoolConfig {
            n_jobs: if smoke { 8 } else { 32 },
            workers: 8,
            input_bytes: if smoke { 1 << 20 } else { 8 << 20 },
            output_bytes: 4096,
            use_xla_engine: false,
            passphrase: "source-sweep".into(),
            data_nodes,
            source,
            ..Default::default()
        };
        let r = run_real_pool(cfg)?;
        anyhow::ensure!(r.errors == 0, "transfer errors in data-source sweep");
        let submit_bytes: u64 = r.bytes_served_per_node.iter().sum();
        let submit_mib: u64 = submit_bytes >> 20;
        let dtn_mib: u64 = r.bytes_served_per_dtn.iter().sum::<u64>() >> 20;
        if data_nodes == 0 {
            funnel_gbps = r.gbps;
        } else if source == SourcePlan::DedicatedDtn {
            // The offload claim is measured, not assumed: a dedicated
            // plan that leaks payload through the funnel fails the bench.
            anyhow::ensure!(
                submit_bytes == 0,
                "dedicated-dtn run served {submit_bytes} B through the submit funnel"
            );
            dtn_gbps = dtn_gbps.max(r.gbps);
        }
        println!(
            "  {:<14}   {:>7.3} Gbps  {:>6.2} s   {:>8}   {:>7}",
            label, r.gbps, r.wall_secs, submit_mib, dtn_mib
        );
        json_rows.push(format!(
            "{{\"sweep\":\"source\",\"source\":\"{}\",\"data_nodes\":{},\"gbps\":{:.4},\
             \"wall_secs\":{:.3},\"submit_mib\":{},\"dtn_mib\":{}}}",
            label, data_nodes, r.gbps, r.wall_secs, submit_mib, dtn_mib
        ));
    }
    println!(
        "  dtn offload vs submit funnel: {:.2}x (dedicated rows verified to serve 0 \
         payload bytes through the submit node)",
        dtn_gbps / funnel_gbps
    );

    println!("\n=== source-selector row (cache-aware vs the round-robin baseline) ===");
    println!("  the benchmark dataset is ONE hard-linked extent, so the cache-aware");
    println!("  selector homes the whole burst on a single data node:");
    println!("  selector          goodput     wall      per-dtn jobs");
    for &(label, selector) in &[
        ("round-robin", SourceSelector::RoundRobin),
        ("cache-aware", SourceSelector::CacheAware),
    ] {
        let cfg = RealPoolConfig {
            n_jobs: if smoke { 8 } else { 32 },
            workers: 8,
            input_bytes: if smoke { 1 << 20 } else { 8 << 20 },
            output_bytes: 4096,
            use_xla_engine: false,
            passphrase: "selector-sweep".into(),
            data_nodes: 2,
            source: SourcePlan::DedicatedDtn,
            source_selector: selector,
            ..Default::default()
        };
        let r = run_real_pool(cfg)?;
        anyhow::ensure!(r.errors == 0, "transfer errors in selector row");
        if selector == SourceSelector::CacheAware {
            // The affinity claim is measured: one extent, one home.
            anyhow::ensure!(
                r.router.routed_per_dtn.iter().filter(|&&c| c > 0).count() == 1,
                "cache-aware spread the single extent: {:?}",
                r.router.routed_per_dtn
            );
        }
        println!(
            "  {:<14}   {:>7.3} Gbps  {:>6.2} s   {:?}",
            label, r.gbps, r.wall_secs, r.router.routed_per_dtn
        );
        json_rows.push(format!(
            "{{\"sweep\":\"source-selector\",\"selector\":\"{}\",\"gbps\":{:.4},\
             \"wall_secs\":{:.3},\"routed_per_dtn\":{:?}}}",
            label, r.gbps, r.wall_secs, r.router.routed_per_dtn
        ));
    }

    if let Ok(dir) = std::env::var("BENCH_REPORT_DIR") {
        std::fs::create_dir_all(&dir).ok();
        let path = format!("{dir}/queue_ablation.json");
        std::fs::write(&path, format!("[{}]\n", json_rows.join(",\n ")))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}
