//! Bench: §II VPN-overlay ablation.
//!
//! Paper: the Calico VPN required for unprivileged pods bottlenecked the
//! submit node at ~25 Gbps; host networking was needed to exceed 90 Gbps.
//! Run: cargo bench --bench vpn_overhead

use htcdm::coordinator::{Experiment, Scenario};

fn main() -> anyhow::Result<()> {
    println!("=== §II ablation: Calico VPN overlay on the submit node ===");
    let host = Experiment::scenario(Scenario::LanPaper).run()?;
    let vpn = Experiment::scenario(Scenario::LanVpn).run()?;
    println!("{}", host.table_row(Some(90.0), Some(32.0)));
    println!("{}", vpn.table_row(Some(25.0), None));
    println!("  metric                paper       measured");
    println!("  VPN throughput cap    ~25 Gbps    {:.1} Gbps", vpn.sustained_gbps());
    println!(
        "  host-network speedup  ~3.6x       {:.1}x",
        host.sustained_gbps() / vpn.sustained_gbps()
    );
    Ok(())
}
