//! Bench: max-min solver + event-loop scaling — the L3 hot path.
//!
//! The paper-scale run re-solves the fluid network on every flow arrival/
//! departure (~20k times for 10k jobs). This bench measures solver cost vs
//! concurrent flow count, the end-to-end events/sec of the engine under
//! BOTH flow solvers, and the sim-vs-real goodput calibration (written as
//! JSON under `BENCH_REPORT_DIR` for the CI artifact).
//! Run: cargo bench --bench netsim_solver
//! CI smoke: cargo bench --bench netsim_solver -- --smoke
//! (one solver point, single iteration, 1/100-scale engine run)

use htcdm::coordinator::engine::EngineSpec;
use htcdm::coordinator::Experiment;
use htcdm::fabric::{run_calibration, CalibrationConfig};
use htcdm::netsim::solver::SolverKind;
use htcdm::netsim::topology::TestbedSpec;
use htcdm::netsim::NetSim;
use htcdm::transfer::ThrottlePolicy;
use htcdm::util::units::{Bytes, Gbps};
use htcdm::util::Prng;

fn main() -> anyhow::Result<()> {
    let smoke =
        std::env::args().any(|a| a == "--smoke") || std::env::var_os("BENCH_SMOKE").is_some();
    if smoke {
        println!("[smoke mode: single-point, single-iteration pass]");
    }
    println!("=== netsim max-min solver scaling ===");
    println!("  flows   links   solve time");
    let flow_sweep: &[usize] = if smoke { &[50] } else { &[50, 200, 800, 3200] };
    for &nflows in flow_sweep {
        let mut net = NetSim::new();
        let mut links = Vec::new();
        for i in 0..10 {
            links.push(net.add_link(&format!("l{i}"), Gbps(100.0)));
        }
        let mut rng = Prng::new(9);
        let mut ids = Vec::new();
        for _ in 0..nflows {
            let a = links[rng.range_usize(0, 4)];
            let b = links[rng.range_usize(5, 9)];
            ids.push(net.start_flow(vec![a, b], 1e12, rng.range_f64(0.05e9, 1e9)));
        }
        // Force repeated re-solves by toggling one link's capacity.
        let t0 = std::time::Instant::now();
        let iters = if smoke { 1 } else { 200 };
        for i in 0..iters {
            net.set_capacity(links[0], Gbps(100.0 - (i % 2) as f64));
            net.resolve();
        }
        let per = t0.elapsed().as_secs_f64() / iters as f64;
        println!("  {nflows:>5}   {:>5}   {:>9.1} us", 10, per * 1e6);
    }

    println!("\n=== end-to-end engine throughput (paper-scale fig1 run, both solvers) ===");
    for kind in [SolverKind::FairShare, SolverKind::TcpDynamic] {
        let mut spec = EngineSpec::paper(TestbedSpec::lan_paper(), ThrottlePolicy::Disabled);
        spec.input_bytes = Bytes(2_000_000_000);
        spec.solver = kind;
        if smoke {
            spec.n_jobs = 100;
        }
        let n_jobs = spec.n_jobs as f64;
        let t0 = std::time::Instant::now();
        let r = Experiment::custom("fig1-perf", spec).run()?;
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "  [{}] {:.0} jobs, {:.1} TB virtual traffic simulated in {:.2} s wall ({:.0} jobs/s)",
            kind.label(),
            n_jobs,
            n_jobs * 2e9 / 1e12,
            wall,
            n_jobs / wall
        );
        println!(
            "  [{}] sustained {:.1} Gbps, makespan {:.1} min",
            kind.label(),
            r.sustained_gbps(),
            r.makespan.as_mins_f64()
        );
    }

    println!("\n=== sim-vs-real goodput calibration (loopback burst, both solvers) ===");
    let cal_cfg = if smoke {
        CalibrationConfig {
            n_jobs: 8,
            input_bytes: 1 << 20,
            workers: 2,
            ..CalibrationConfig::default()
        }
    } else {
        CalibrationConfig {
            n_jobs: 48,
            input_bytes: 8 << 20,
            workers: 4,
            ..CalibrationConfig::default()
        }
    };
    let cal = run_calibration(&cal_cfg)?;
    println!(
        "  real-tcp: {:.3} Gbps aggregate, {:.1} MB/s per stream",
        cal.real_gbps,
        cal.real_stream_bps / 1e6
    );
    for p in &cal.points {
        println!(
            "  {:>12}: {:.3} Gbps predicted (ratio {:.3}{})",
            p.solver,
            p.sim_gbps,
            p.ratio,
            if (0.5..=2.0).contains(&p.ratio) { ", in band" } else { ", OUT OF BAND" }
        );
    }
    if let Some(dir) = std::env::var_os("BENCH_REPORT_DIR") {
        let dir = std::path::PathBuf::from(dir);
        std::fs::create_dir_all(&dir)?;
        let path = dir.join("solver_calibration.json");
        std::fs::write(&path, cal.to_json())?;
        println!("  wrote {}", path.display());
    }
    anyhow::ensure!(
        cal.within_band(2.0),
        "solver calibration left the factor-2 band: {}",
        cal.to_json()
    );
    Ok(())
}
