//! Bench: multi-site WAN federation — the petascale transfer-week shape.
//!
//! Runs the `petascale-week-3x2` scenario (3 federated sites, round-robin
//! site selection, per-pair WAN links) and reports the site×site goodput
//! matrix, then runs the federated sim-vs-real site calibration on a
//! 2-site loopback burst. Gates: the scenario must push a round-robin
//! share of its goodput across the WAN (cross-site fraction within the
//! factor-2 band around the ideal 2/3), and the calibration matrices must
//! agree within the factor-2 band. Both records land in
//! `wan_federation.json` under `BENCH_REPORT_DIR` for the CI artifact.
//!
//! Run: cargo bench --bench wan_federation
//! CI smoke: cargo bench --bench wan_federation -- --smoke
//! (1/33-scale burst, small calibration run)

use htcdm::coordinator::{Experiment, Scenario};
use htcdm::fabric::{run_site_calibration, CalibrationConfig};

fn main() -> anyhow::Result<()> {
    let smoke =
        std::env::args().any(|a| a == "--smoke") || std::env::var_os("BENCH_SMOKE").is_some();
    if smoke {
        println!("[smoke mode: scaled-down burst and calibration]");
    }

    println!("=== petascale-week-3x2: 3-site federated transfer week ===");
    let mut exp = Experiment::scenario(Scenario::PetascaleWeek3x2);
    if smoke {
        exp.spec.n_jobs = 300;
    }
    let n_jobs = exp.spec.n_jobs;
    let t0 = std::time::Instant::now();
    let report = exp.run()?;
    let wall = t0.elapsed().as_secs_f64();
    let total_bytes: u64 = report.site_matrix_bytes.iter().flatten().sum();
    let cross_bytes = report.cross_site_bytes();
    let cross_fraction = cross_bytes as f64 / (total_bytes as f64).max(1.0);
    let makespan_s = report.makespan.as_secs_f64().max(1e-9);
    let total_gbps = total_bytes as f64 * 8.0 / makespan_s / 1e9;
    let cross_gbps = cross_bytes as f64 * 8.0 / makespan_s / 1e9;
    println!(
        "  {} jobs over {} sites ({}) in {:.2} s wall | makespan {:.1} min",
        n_jobs,
        report.n_sites,
        report.site_selector,
        wall,
        report.makespan.as_mins_f64()
    );
    println!(
        "  sustained {total_gbps:.1} Gbps total | {cross_gbps:.1} Gbps cross-site \
         ({:.0}% of bytes crossed the WAN)",
        cross_fraction * 100.0
    );
    println!("  site×site GB:");
    for (s, row) in report.site_matrix_bytes.iter().enumerate() {
        let cells: Vec<String> = row.iter().map(|b| format!("{:>8.1}", *b as f64 / 1e9)).collect();
        println!("    s{s} -> [{}]", cells.join(" "));
    }
    // Round-robin over 3 sites should send ~2/3 of the bytes cross-site;
    // gate the observed fraction inside the factor-2 band around that.
    let ideal = 2.0 / 3.0;
    anyhow::ensure!(
        cross_gbps > 0.0 && cross_fraction >= ideal / 2.0 && cross_fraction <= (ideal * 2.0).min(1.0),
        "cross-site share {:.3} left the factor-2 band around {:.3} (cross {:.1} of {:.1} Gbps)",
        cross_fraction,
        ideal,
        cross_gbps,
        total_gbps
    );

    println!("\n=== federated sim-vs-real site calibration (2-site loopback burst) ===");
    let cal_cfg = if smoke {
        CalibrationConfig {
            n_jobs: 8,
            input_bytes: 1 << 20,
            workers: 2,
            ..CalibrationConfig::default()
        }
    } else {
        CalibrationConfig {
            n_jobs: 48,
            input_bytes: 8 << 20,
            workers: 4,
            ..CalibrationConfig::default()
        }
    };
    let cal = run_site_calibration(&cal_cfg, 2)?;
    println!(
        "  real {:.3} Gbps vs sim {:.3} Gbps (ratio {:.3}) | row ratios {:?}",
        cal.real_gbps,
        cal.sim_gbps,
        cal.ratio,
        cal.row_ratios()
            .iter()
            .map(|r| (r * 1000.0).round() / 1000.0)
            .collect::<Vec<_>>()
    );
    println!("  real matrix {:?}", cal.real_matrix);
    println!("  sim  matrix {:?}", cal.sim_matrix);

    if let Some(dir) = std::env::var_os("BENCH_REPORT_DIR") {
        let dir = std::path::PathBuf::from(dir);
        std::fs::create_dir_all(&dir)?;
        let path = dir.join("wan_federation.json");
        let json = format!(
            "{{\"scenario\":{{\"name\":\"{}\",\"jobs\":{},\"total_gbps\":{:.6},\
             \"cross_site_gbps\":{:.6},\"cross_site_fraction\":{:.6},\"matrix\":{}}},\
             \"calibration\":{}}}",
            report.label,
            n_jobs,
            total_gbps,
            cross_gbps,
            cross_fraction,
            report.site_matrix_json(),
            cal.to_json()
        );
        std::fs::write(&path, json)?;
        println!("  wrote {}", path.display());
    }
    anyhow::ensure!(
        cal.within_band(2.0),
        "site calibration left the factor-2 band: {}",
        cal.to_json()
    );
    Ok(())
}
