//! Bench: regenerate the paper's Fig. 2 + §IV narrative metrics (WAN).
//!
//! Paper: ~60 Gbps sustained across the US (58 ms RTT), 10k jobs in 49 min,
//! median input transfer 3.3 min, other metrics comparable to LAN.
//! Run: cargo bench --bench fig2_wan

use htcdm::coordinator::{Experiment, Scenario};

fn main() -> anyhow::Result<()> {
    println!("=== Fig. 2 / §IV: cross-US WAN benchmark (UCSD -> NY, 58 ms RTT) ===");
    let t0 = std::time::Instant::now();
    let lan = Experiment::scenario(Scenario::LanPaper).run()?;
    let wan = Experiment::scenario(Scenario::WanPaper).run()?;
    println!("{}", wan.table_row(Some(60.0), Some(49.0)));
    println!("  metric                paper      measured");
    println!("  sustained throughput  60 Gbps    {:.1} Gbps", wan.sustained_gbps());
    println!("  makespan              49 min     {:.1} min", wan.makespan.as_mins_f64());
    println!(
        "  median input transfer 3.3 min*   {:.2} min (queue-incl) / {:.2} min (wire)",
        wan.median_input_transfer.as_mins_f64(),
        wan.median_wire_transfer.as_mins_f64()
    );
    println!("  errors                0          {}", wan.errors);
    println!("  shape checks:");
    println!(
        "    LAN/WAN throughput ratio: paper 90/60 = 1.50, measured {:.2}",
        lan.sustained_gbps() / wan.sustained_gbps()
    );
    println!(
        "    WAN/LAN makespan ratio:   paper 49/32 = 1.53, measured {:.2}",
        wan.makespan.as_secs_f64() / lan.makespan.as_secs_f64()
    );
    println!(
        "    WAN/LAN transfer-time ratio: paper 3.3/2.6 = 1.27, measured {:.2}",
        wan.median_wire_transfer.as_secs_f64() / lan.median_wire_transfer.as_secs_f64()
    );
    println!("\nFig. 2 reproduction (5-min bins):\n{}", wan.figure(100.0));
    println!("[bench wall time: {:.2} s]", t0.elapsed().as_secs_f64());
    Ok(())
}
