//! Bench: regenerate the paper's Fig. 1 + §III narrative metrics (LAN).
//!
//! Paper: sustained ~90 Gbps on the submit 100 Gbps NIC; 10k jobs done in
//! 32 min; median job runtime 5 s; median input transfer 2.6 min; no errors.
//! Run: cargo bench --bench fig1_lan

use htcdm::coordinator::{Experiment, Scenario};

fn main() -> anyhow::Result<()> {
    println!("=== Fig. 1 / §III: LAN 100 Gbps benchmark (10k x 2 GB, 200 slots) ===");
    let t0 = std::time::Instant::now();
    let r = Experiment::scenario(Scenario::LanPaper).run()?;
    println!("{}", r.table_row(Some(90.0), Some(32.0)));
    println!("  metric                paper      measured");
    println!("  sustained throughput  90 Gbps    {:.1} Gbps", r.sustained_gbps());
    println!("  peak bin              ~93 Gbps   {:.1} Gbps", r.peak.0);
    println!("  makespan              32 min     {:.1} min", r.makespan.as_mins_f64());
    println!("  median job runtime    5 s        {:.1} s", r.median_runtime_s);
    println!(
        "  median input transfer 2.6 min*   {:.2} min (queue-incl) / {:.2} min (wire)",
        r.median_input_transfer.as_mins_f64(),
        r.median_wire_transfer.as_mins_f64()
    );
    println!("  errors                0          {}", r.errors);
    println!("  * the paper's 2.6 min is inconsistent with");
    println!("    200 slots at 90 Gbps; our emergent value is reported.");
    println!("\nFig. 1 reproduction (5-min bins):\n{}", r.figure(100.0));
    println!("[bench wall time: {:.2} s]", t0.elapsed().as_secs_f64());
    Ok(())
}
