//! Bench: million-owner control plane — router decision throughput.
//!
//! The paper's burst came from one benchmark user, but a campus pool
//! routes for every owner at once. This bench proves the sharded router
//! state keeps the per-decision cost flat as the owner population grows
//! from 10^3 to 10^6 across a 100-node x 100-DTN fleet:
//!
//! * a DECISION sweep: policies x source selectors x owner counts
//!   {1e3, 1e5, 1e6}, each combo routing a fixed-size burst through a
//!   sliding in-flight window (request + complete, the full control
//!   loop), reporting ns/decision and decisions/sec,
//! * a STATS-MERGE row per combo: the cost of folding per-node,
//!   per-shard accounting into one `MoverStats` under that load,
//! * a SCALING GATE per combo: the 1e6-owner decision cost must stay
//!   within 3x the 1e3-owner cost — the flat-cost claim, asserted
//!   in-bench so CI fails if sharding regresses,
//! * a BATCH row: `route_batch` in negotiator-style cycles vs the same
//!   burst routed one `request` at a time, with the decisions checked
//!   identical (the batch API is a pure batching of the single path).
//!
//! Every row is also recorded as a JSON object; set `BENCH_REPORT_DIR`
//! to write them to `router_throughput.json` (the CI bench-smoke job
//! uploads them as artifacts).
//!
//! Run: cargo bench --bench router_throughput
//! CI smoke: cargo bench --bench router_throughput -- --smoke
//! (fewer combos, {1e3, 1e6} owners only; the 3x gate still runs)

use std::collections::VecDeque;
use std::time::Instant;

use htcdm::mover::{
    PoolRouter, RouterConfig, RouterPolicy, ShadowPool, SourcePlan, SourceSelector,
    TransferRequest,
};
use htcdm::storage::ExtentId;
use htcdm::transfer::ThrottlePolicy;

/// `--smoke` (or `BENCH_SMOKE=1`): shrink the sweep so CI can execute
/// the bench end-to-end on each PR. The scaling gate still runs.
fn smoke_mode() -> bool {
    std::env::args().any(|a| a == "--smoke") || std::env::var_os("BENCH_SMOKE").is_some()
}

const N_NODES: u32 = 100;
const N_DTNS: usize = 100;
const N_EXTENTS: u64 = 1024;
/// Sliding in-flight window: matches a saturated pool where completes
/// arrive at roughly the admission rate.
const WINDOW: usize = 4096;

fn selector_label(s: SourceSelector) -> &'static str {
    match s {
        SourceSelector::RoundRobin => "round-robin",
        SourceSelector::CacheAware => "cache-aware",
        SourceSelector::OwnerAffinity => "owner-affinity",
        SourceSelector::WeightedByCapacity => "weighted",
    }
}

fn build_router(policy: RouterPolicy, selector: SourceSelector) -> PoolRouter {
    let nodes = (0..N_NODES)
        .map(|_| ShadowPool::sim(1, ThrottlePolicy::Disabled.into()))
        .collect();
    PoolRouter::from_config(
        nodes,
        vec![1.0; N_NODES as usize],
        policy,
        RouterConfig {
            source_plan: SourcePlan::DedicatedDtn,
            dtn_capacity: vec![1.0; N_DTNS],
            source_selector: selector,
            ..RouterConfig::default()
        },
    )
}

/// Deterministic owner pick: a Knuth multiplicative walk over the owner
/// population, so every owner count sees the same request stream shape.
fn owner_index(i: u32, n_owners: usize) -> usize {
    ((i as u64).wrapping_mul(2_654_435_761) % n_owners as u64) as usize
}

struct ComboTiming {
    ns_per_decision: f64,
    stats_merge_ns: f64,
    routed: usize,
}

/// Route `decisions` requests through a fresh router with a sliding
/// completion window, then time the stats merge under the final load.
fn run_combo(
    policy: RouterPolicy,
    selector: SourceSelector,
    owners: &[String],
    decisions: u32,
) -> ComboTiming {
    let mut router = build_router(policy, selector);
    let mut inflight: VecDeque<u32> = VecDeque::with_capacity(WINDOW + 1);
    let mut routed = 0usize;
    let t0 = Instant::now();
    for t in 0..decisions {
        let idx = owner_index(t, owners.len());
        let req = TransferRequest::new(t, owners[idx].as_str(), 1 << 20)
            .with_extent(ExtentId(idx as u64 % N_EXTENTS));
        routed += router.request(req).len();
        inflight.push_back(t);
        if inflight.len() > WINDOW {
            let done = inflight.pop_front().expect("window is non-empty");
            router.complete(done);
        }
    }
    let route_elapsed = t0.elapsed();

    // Stats-merge cost: fold the per-node, per-shard accounting into one
    // MoverStats (plus the router-level view) under the loaded maps.
    const MERGE_ITERS: u32 = 32;
    let t1 = Instant::now();
    for _ in 0..MERGE_ITERS {
        std::hint::black_box(router.stats());
        std::hint::black_box(router.router_stats());
    }
    let merge_elapsed = t1.elapsed();

    ComboTiming {
        ns_per_decision: route_elapsed.as_nanos() as f64 / decisions as f64,
        stats_merge_ns: merge_elapsed.as_nanos() as f64 / MERGE_ITERS as f64,
        routed,
    }
}

/// Best-of-2 so one scheduler hiccup can't fail the scaling gate.
fn run_combo_best(
    policy: RouterPolicy,
    selector: SourceSelector,
    owners: &[String],
    decisions: u32,
) -> ComboTiming {
    let a = run_combo(policy, selector, owners, decisions);
    let b = run_combo(policy, selector, owners, decisions);
    if b.ns_per_decision < a.ns_per_decision {
        b
    } else {
        a
    }
}

fn main() -> anyhow::Result<()> {
    let smoke = smoke_mode();
    let mut json_rows: Vec<String> = Vec::new();
    if smoke {
        println!("[smoke mode: 2 combos, {{1e3, 1e6}} owners, short bursts]");
    }

    let owner_counts: &[usize] = if smoke {
        &[1_000, 1_000_000]
    } else {
        &[1_000, 100_000, 1_000_000]
    };
    let decisions: u32 = if smoke { 120_000 } else { 300_000 };
    let combos: &[(RouterPolicy, SourceSelector)] = if smoke {
        &[
            (RouterPolicy::LeastLoaded, SourceSelector::RoundRobin),
            (RouterPolicy::OwnerAffinity, SourceSelector::CacheAware),
        ]
    } else {
        &[
            (RouterPolicy::RoundRobin, SourceSelector::RoundRobin),
            (RouterPolicy::RoundRobin, SourceSelector::CacheAware),
            (RouterPolicy::RoundRobin, SourceSelector::OwnerAffinity),
            (RouterPolicy::LeastLoaded, SourceSelector::RoundRobin),
            (RouterPolicy::LeastLoaded, SourceSelector::CacheAware),
            (RouterPolicy::LeastLoaded, SourceSelector::OwnerAffinity),
            (RouterPolicy::OwnerAffinity, SourceSelector::RoundRobin),
            (RouterPolicy::OwnerAffinity, SourceSelector::CacheAware),
            (RouterPolicy::OwnerAffinity, SourceSelector::OwnerAffinity),
        ]
    };

    // One owner table at the max population; smaller counts slice it so
    // the same names (and extents) recur across scales.
    let max_owners = *owner_counts.iter().max().expect("non-empty owner counts");
    let owners: Vec<String> = (0..max_owners).map(|i| format!("u{i}")).collect();

    println!(
        "=== router decision sweep ({N_NODES} nodes x {N_DTNS} DTNs, \
         {decisions} decisions/combo, window {WINDOW}) ==="
    );
    println!("  policy       selector         owners     ns/decision   Mdec/s   stats-merge");
    let gate_limit = 3.0;
    for &(policy, selector) in combos {
        let mut small_ns = 0.0f64;
        for &n_owners in owner_counts {
            let t = run_combo_best(policy, selector, &owners[..n_owners], decisions);
            anyhow::ensure!(
                t.routed == decisions as usize,
                "{} decisions routed, expected {decisions}",
                t.routed
            );
            let mdec_per_sec = 1e3 / t.ns_per_decision;
            println!(
                "  {:<12} {:<15} {:>8}   {:>9.1} ns  {:>6.2}   {:>9.1} us",
                policy.label(),
                selector_label(selector),
                n_owners,
                t.ns_per_decision,
                mdec_per_sec,
                t.stats_merge_ns / 1e3
            );
            json_rows.push(format!(
                "{{\"section\":\"decisions\",\"policy\":\"{}\",\"selector\":\"{}\",\
                 \"owners\":{},\"decisions\":{},\"ns_per_decision\":{:.1},\
                 \"decisions_per_sec\":{:.0},\"stats_merge_ns\":{:.0}}}",
                policy.label(),
                selector_label(selector),
                n_owners,
                decisions,
                t.ns_per_decision,
                1e9 / t.ns_per_decision,
                t.stats_merge_ns
            ));
            if n_owners == owner_counts[0] {
                small_ns = t.ns_per_decision;
            } else if n_owners == max_owners {
                // The flat-cost gate: a million owners may not cost more
                // than 3x a thousand owners on the same decision stream.
                let ratio = t.ns_per_decision / small_ns.max(1.0);
                println!(
                    "    scaling {}k -> {}M owners: {:.2}x (gate {:.1}x)",
                    owner_counts[0] / 1_000,
                    max_owners / 1_000_000,
                    ratio,
                    gate_limit
                );
                json_rows.push(format!(
                    "{{\"section\":\"scaling-gate\",\"policy\":\"{}\",\"selector\":\"{}\",\
                     \"owners_small\":{},\"owners_big\":{},\"ratio\":{:.3},\"limit\":{:.1}}}",
                    policy.label(),
                    selector_label(selector),
                    owner_counts[0],
                    max_owners,
                    ratio,
                    gate_limit
                ));
                anyhow::ensure!(
                    ratio <= gate_limit,
                    "decision cost not flat for {}/{}: {:.2}x from {} to {} owners \
                     (gate {:.1}x)",
                    policy.label(),
                    selector_label(selector),
                    ratio,
                    owner_counts[0],
                    max_owners,
                    gate_limit
                );
            }
        }
    }

    println!("\n=== batched admission: route_batch cycles vs single requests ===");
    let batch_reqs: u32 = if smoke { 20_000 } else { 100_000 };
    let cycle = 256usize;
    let n_owners = owner_counts[0];
    let make_reqs = || -> Vec<TransferRequest> {
        (0..batch_reqs)
            .map(|t| {
                let idx = owner_index(t, n_owners);
                TransferRequest::new(t, owners[idx].as_str(), 1 << 20)
                    .with_extent(ExtentId(idx as u64 % N_EXTENTS))
            })
            .collect()
    };
    let (policy, selector) = (RouterPolicy::LeastLoaded, SourceSelector::CacheAware);

    let mut single_router = build_router(policy, selector);
    let t0 = Instant::now();
    let mut single_out = Vec::with_capacity(batch_reqs as usize);
    for req in make_reqs() {
        single_out.extend(single_router.request(req));
    }
    let single_ns = t0.elapsed().as_nanos() as f64 / batch_reqs as f64;

    let mut batch_router = build_router(policy, selector);
    let all = make_reqs();
    let t1 = Instant::now();
    let mut batch_out = Vec::with_capacity(batch_reqs as usize);
    for chunk in all.chunks(cycle) {
        batch_out.extend(batch_router.route_batch(chunk.to_vec()));
    }
    let batch_ns = t1.elapsed().as_nanos() as f64 / batch_reqs as f64;

    // The batch API is a pure batching of the single path: identical
    // decisions and identical accounting, or the bench fails.
    anyhow::ensure!(
        single_out == batch_out,
        "route_batch diverged from single routing"
    );
    anyhow::ensure!(
        single_router.stats() == batch_router.stats(),
        "route_batch accounting diverged from single routing"
    );
    println!("  mode        reqs      ns/decision");
    println!("  single   {:>8}   {:>9.1} ns", batch_reqs, single_ns);
    println!(
        "  batch    {:>8}   {:>9.1} ns  (cycle {cycle}, decisions verified identical)",
        batch_reqs, batch_ns
    );
    json_rows.push(format!(
        "{{\"section\":\"batch\",\"mode\":\"single\",\"reqs\":{batch_reqs},\
         \"ns_per_decision\":{single_ns:.1}}}"
    ));
    json_rows.push(format!(
        "{{\"section\":\"batch\",\"mode\":\"cycle-{cycle}\",\"reqs\":{batch_reqs},\
         \"ns_per_decision\":{batch_ns:.1}}}"
    ));

    if let Ok(dir) = std::env::var("BENCH_REPORT_DIR") {
        std::fs::create_dir_all(&dir).ok();
        let path = format!("{dir}/router_throughput.json");
        std::fs::write(&path, format!("[{}]\n", json_rows.join(",\n ")))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}
