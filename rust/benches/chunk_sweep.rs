//! Bench: sealed-stream chunk-size sweep on the real-mode loopback fabric.
//!
//! Frame size trades per-frame overhead (header+digest+engine dispatch)
//! against latency and memory; this locates the knee for the native
//! engine. See docs/ARCHITECTURE.md §Data-path performance for the
//! byte-path framing this sweeps over.
//! Run: cargo bench --bench chunk_sweep

use htcdm::fabric::{run_real_pool, RealPoolConfig};

fn main() -> anyhow::Result<()> {
    println!("=== sealed-stream chunk-size sweep (loopback, native engine) ===");
    println!("  chunk      goodput    median transfer");
    for chunk_words in [256usize, 1024, 4096, 16384, 65536] {
        let cfg = RealPoolConfig {
            n_jobs: 16,
            workers: 4,
            input_bytes: 8 << 20,
            output_bytes: 4096,
            chunk_words,
            use_xla_engine: false,
            passphrase: "bench".into(),
            ..Default::default()
        };
        let r = run_real_pool(cfg)?;
        anyhow::ensure!(r.errors == 0, "transfer errors in sweep");
        println!(
            "  {:>6} KiB  {:>7.3} Gbps   {:>6.3} s",
            chunk_words * 4 / 1024,
            r.gbps,
            r.transfer_secs.median()
        );
    }
    Ok(())
}
