//! Bench: data-plane line rate — can the sealed-transfer crypto keep up
//! with a 100 Gbps NIC, as the paper's 8-core EPYC did with AES-NI?
//!
//! Measures the native engines (ChaCha20, AES-256-CTR, integrity-only) per
//! chunk size, and — with HTCDM_BENCH_XLA=1 — the PJRT artifact engine
//! (interpret-mode Pallas; see docs/ARCHITECTURE.md §Data-path performance
//! for why that path is structural, not line-rate, on CPU).
//! Run: cargo bench --bench crypto_line_rate

use htcdm::runtime::engine::{Kind, NativeEngine, SealEngine};
use htcdm::security::Method;
use htcdm::util::Prng;

fn bench_engine(label: &str, engine: &mut dyn SealEngine, words: usize, secs: f64) -> f64 {
    let mut rng = Prng::new(1);
    let mut data: Vec<u32> = (0..words).map(|_| rng.next_u32()).collect();
    let key = [7u32; 8];
    let nonce = [1, 2, 3];
    // Warmup.
    engine.process(Kind::Seal, &key, &nonce, 0, &mut data).unwrap();
    let t0 = std::time::Instant::now();
    let mut bytes = 0u64;
    let mut ctr = 0u32;
    while t0.elapsed().as_secs_f64() < secs {
        engine.process(Kind::Seal, &key, &nonce, ctr, &mut data).unwrap();
        bytes += (words * 4) as u64;
        ctr = ctr.wrapping_add((words / 16) as u32);
    }
    let gbps = bytes as f64 * 8.0 / t0.elapsed().as_secs_f64() / 1e9;
    println!("  {label:<28} {words:>8} words   {gbps:>8.3} Gbps");
    gbps
}

fn main() {
    println!("=== Data-plane line rate (seal = encrypt + digest), single thread ===");
    println!("  paper context: submit node sustained 90 Gbps AES on 8 cores");
    for words in [1024usize * 16, 4096 * 16, 16384 * 16] {
        bench_engine(
            "native ChaCha20+poly16",
            &mut NativeEngine::new(Method::Chacha20),
            words,
            1.0,
        );
    }
    bench_engine(
        "native AES-256-CTR+poly16",
        &mut NativeEngine::new(Method::Aes256Ctr),
        1024 * 16,
        1.0,
    );
    bench_engine(
        "integrity only (poly16)",
        &mut NativeEngine::new(Method::Plain),
        1024 * 16,
        1.0,
    );
    let chacha_1 = bench_engine(
        "native ChaCha20 (64k chunks)",
        &mut NativeEngine::new(Method::Chacha20),
        1024 * 16,
        1.0,
    );
    println!(
        "  -> 8 cores x {chacha_1:.1} Gbps = {:.0} Gbps aggregate ({} the 90 Gbps the paper needed)",
        8.0 * chacha_1,
        if 8.0 * chacha_1 >= 90.0 { "meets" } else { "below" }
    );

    if std::env::var("HTCDM_BENCH_XLA").as_deref() == Ok("1") {
        println!("\n  PJRT artifact engine (interpret-mode Pallas, 64k geometry):");
        match htcdm::runtime::Manifest::load(htcdm::runtime::Manifest::default_dir())
            .and_then(|m| htcdm::runtime::SealRuntime::load(&m, &["64k"]))
        {
            Ok(rt) => {
                let mut e = htcdm::runtime::engine::XlaEngine::new(rt);
                bench_engine("xla-pjrt ChaCha20+poly16", &mut e, 1024 * 16, 3.0);
            }
            Err(e) => println!("  (unavailable: {e:#})"),
        }
    } else {
        println!("\n  (set HTCDM_BENCH_XLA=1 to also bench the PJRT artifact engine;");
        println!("   skipped by default: XLA compilation of the artifact takes ~2 min)");
    }
}
