#!/usr/bin/env python3
"""Check intra-doc markdown links.

Scans the given markdown files (default: docs/*.md plus ROADMAP.md) for
inline links `[text](target)` and verifies that

* relative file targets exist (resolved against the linking file's dir),
* `#anchor` fragments match a heading in the target file (GitHub-style
  slugs: lowercase, punctuation stripped, spaces -> hyphens).

External links (http/https/mailto) are skipped — this is an offline
repo and CI must not depend on the network. Exits non-zero with one
line per broken link.
"""

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")
CODE_FENCE_RE = re.compile(r"^(```|~~~)")


def slugify(heading: str) -> str:
    """Approximate GitHub's anchor slugger."""
    text = heading.strip().lower().replace("`", "")
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def headings(path: Path) -> set[str]:
    slugs = set()
    in_fence = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if m:
            slugs.add(slugify(m.group(1)))
    return slugs


def links(path: Path):
    in_fence = False
    for lineno, line in enumerate(path.read_text(encoding="utf-8").splitlines(), 1):
        if CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in LINK_RE.finditer(line):
            yield lineno, m.group(1)


def main(argv: list[str]) -> int:
    if argv:
        files = [Path(a) for a in argv]
    else:
        files = sorted(Path("docs").glob("*.md")) + [Path("ROADMAP.md")]
    files = [f for f in files if f.exists()]
    if not files:
        print("check_doc_links: no markdown files found", file=sys.stderr)
        return 2

    errors = []
    for f in files:
        for lineno, target in links(f):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, anchor = target.partition("#")
            dest = f if not path_part else (f.parent / path_part)
            if not dest.exists():
                errors.append(f"{f}:{lineno}: broken link target '{target}'")
                continue
            if anchor and dest.suffix == ".md":
                if slugify(anchor) not in headings(dest):
                    errors.append(
                        f"{f}:{lineno}: anchor '#{anchor}' not found in {dest}"
                    )

    for e in errors:
        print(e, file=sys.stderr)
    checked = ", ".join(str(f) for f in files)
    if errors:
        print(f"check_doc_links: {len(errors)} broken link(s) in {checked}",
              file=sys.stderr)
        return 1
    print(f"check_doc_links: OK ({checked})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
